//! `expt` — the scenario-matrix experiment runner.
//!
//! The paper's headline numbers (10.8x cost vs. mainstream serverless,
//! 4.8x fewer SLO violations vs. spatio-temporal sharing) are not single
//! simulations but **grids** of `platform × workload preset × seed` runs.
//! This module makes that grid a first-class artifact:
//!
//! * [`PlatformRegistry`] (see [`platform`]) describes the comparison
//!   surface as open [`PlatformSpec`] descriptors — the stock trio, the
//!   single-axis / static-predictor ablations, and any caller-registered
//!   comparator;
//! * [`ScenarioMatrix`] declares the grid (platform names resolved against
//!   the registry, fleet names against the [`FleetRegistry`], presets,
//!   seeds, trace length, cluster size, base rate);
//! * [`ScenarioMatrix::run`] shards the cells across
//!   [`ThreadPool::scope_for`] — each cell is an independent, fully-seeded
//!   [`run_sim`] invocation, so results are **bit-identical for any
//!   `--jobs` setting**;
//! * [`MatrixReport`] aggregates per-cell [`CellResult`]s into paper-style
//!   comparison tables (SLO-violation rate, P99 latency, GPU-seconds,
//!   $/1K requests, baseline-over-HAS ratios) and serialises the whole
//!   grid to `BENCH_sim.json` through [`crate::util::json`] — the
//!   machine-readable perf trajectory later PRs regress against.
//!
//! The `has-gpu expt` subcommand is the CLI entry point; `has-gpu simulate`
//! is a single-cell special case of the same path. For stock-trio grids the
//! export is byte-identical to the pre-registry (closed-enum) output —
//! pinned by `rust/tests/expt_golden.rs`; ablation platforms and
//! non-default fleets extend the grid without perturbing existing cells
//! (the default `uniform-v100` fleet exports no fleet keys at all).

pub mod fleet;
pub mod platform;

pub use fleet::{FleetRegistry, FleetSpec, DEFAULT_FLEET};
pub use platform::{
    billing_label, PlatformGroup, PlatformRegistry, PlatformSpec, PolicyFactory, PredictorSel,
};

use crate::cluster::FunctionSpec;
use crate::metrics::RunReport;
use crate::model::zoo::{zoo_graph, ZooModel};
use crate::perf::PerfModel;
use crate::sim::{fault_name_menu, fault_spec_from_name, run_sim, SimConfig, NO_FAULTS};
use crate::util::bench::ascii_table;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use crate::workload::{Preset, TraceGen, ALL_PRESETS};
use std::sync::Mutex;

/// The registry name of the paper's own platform — the denominator of every
/// headline ratio.
pub const HAS_GPU: &str = "has-gpu";

/// The benchmark function set shared by every cell (paper §4: MLPerf-style
/// zoo minus ResNet-152, which stays the Fig. 4 profiling subject).
pub fn experiment_functions() -> Vec<FunctionSpec> {
    let perf = PerfModel::default();
    crate::model::zoo::ALL_ZOO
        .iter()
        .filter(|m| !matches!(m, ZooModel::ResNet152))
        .map(|&m| {
            let graph = zoo_graph(m);
            let baseline = perf.latency(&graph, 1, 1.0, 1.0);
            let slo = baseline * 3.0;
            let batch = [16u32, 8, 4, 2, 1]
                .into_iter()
                .find(|&b| perf.latency(&graph, b, 1.0, 1.0) <= slo * 0.5)
                .unwrap_or(1);
            FunctionSpec {
                name: graph.name.clone(),
                slo,
                batch,
                graph,
                artifact: None,
            }
        })
        .collect()
}

/// The workflow a pipeline preset drives, if any. Preset and registry
/// names coincide by construction, so the lookup cannot miss for the two
/// pipeline presets and is `None` for everything else.
pub fn pipeline_workflow(preset: Preset) -> Option<crate::workflow::Workflow> {
    let name = match preset {
        Preset::PipelineVision => "pipeline-vision",
        Preset::PipelineMixed => "pipeline-mixed",
        _ => return None,
    };
    Some(
        crate::workflow::WorkflowRegistry::default()
            .get(name)
            .expect("pipeline preset workflow is registered")
            .clone(),
    )
}

/// One grid cell: a platform (by registry name) run against one preset
/// instance at one seed, on one named fleet, under one fault preset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScenarioCell {
    pub platform: String,
    pub preset: Preset,
    pub seed: u64,
    /// Fleet registry name ([`DEFAULT_FLEET`] = the pre-fleet homogeneous
    /// V100 cluster; omitted from the export for byte-stability).
    pub fleet: String,
    /// Fault preset name ([`NO_FAULTS`] = zero fault events scheduled;
    /// omitted from the export for byte-stability).
    pub fault: String,
}

/// Declarative description of the experiment grid. `platforms` holds
/// canonical registry names (use [`parse_platforms`] /
/// [`PlatformRegistry::resolve`] to produce them); `registry` supplies the
/// descriptors [`ScenarioMatrix::run_cell`] builds each cell from;
/// `fleets` holds canonical [`FleetRegistry`] names resolved the same way.
#[derive(Clone, Debug)]
pub struct ScenarioMatrix {
    pub platforms: Vec<String>,
    pub registry: PlatformRegistry,
    pub presets: Vec<Preset>,
    pub seeds: Vec<u64>,
    /// Trace length per cell in virtual seconds.
    pub seconds: usize,
    /// Cluster size per cell (split across a fleet's classes by weight).
    pub gpus: usize,
    /// Mean request rate the trace synthesiser oscillates around.
    pub rps: f64,
    /// Fleet names per cell column; default `[uniform-v100]` — the
    /// byte-stable pre-fleet grid.
    pub fleets: Vec<String>,
    pub fleet_registry: FleetRegistry,
    /// Fault preset names per cell column (see
    /// [`crate::sim::fault_table`]); default `[no-faults]` — the
    /// byte-stable zero-fault grid.
    pub faults: Vec<String>,
}

impl Default for ScenarioMatrix {
    fn default() -> Self {
        let registry = PlatformRegistry::default();
        let platforms = registry
            .group_names(PlatformGroup::Stock)
            .into_iter()
            .map(str::to_string)
            .collect();
        ScenarioMatrix {
            platforms,
            registry,
            presets: vec![Preset::Standard],
            seeds: vec![11],
            seconds: 300,
            gpus: 10,
            rps: 150.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            fleet_registry: FleetRegistry::default(),
            faults: vec![NO_FAULTS.to_string()],
        }
    }
}

impl ScenarioMatrix {
    /// The grid cells in canonical (preset-major, then fault, then fleet,
    /// then platform, then seed) order. The order is part of the output
    /// contract: aggregation and serialisation walk it deterministically,
    /// and with the single default fault/fleet it is exactly the pre-fault
    /// (preset, fleet, platform, seed) walk.
    pub fn cells(&self) -> Vec<ScenarioCell> {
        let mut out = Vec::with_capacity(
            self.presets.len()
                * self.faults.len()
                * self.fleets.len()
                * self.platforms.len()
                * self.seeds.len(),
        );
        for &preset in &self.presets {
            for fault in &self.faults {
                for fleet in &self.fleets {
                    for platform in &self.platforms {
                        for &seed in &self.seeds {
                            out.push(ScenarioCell {
                                platform: platform.clone(),
                                preset,
                                seed,
                                fleet: fleet.clone(),
                                fault: fault.clone(),
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Run one cell end-to-end. Everything a cell touches (trace, policy,
    /// predictor, cluster, RNG streams) is built locally from the cell's
    /// coordinates through its [`PlatformSpec`], so a cell's result is a
    /// pure function of `(platform, preset, seed, matrix config)` — the
    /// property behind the `--jobs`-independence guarantee.
    ///
    /// Panics if `cell.platform` is not in `self.registry` — construct the
    /// platform list through [`parse_platforms`] / `registry.resolve` to
    /// guarantee membership.
    pub fn run_cell(&self, cell: &ScenarioCell) -> (RunReport, CellResult) {
        let spec = self.registry.get(&cell.platform).unwrap_or_else(|| {
            panic!(
                "platform '{}' not in registry (known: {})",
                cell.platform,
                self.registry.names().join(", ")
            )
        });
        let fleet = self.fleet_registry.get(&cell.fleet).unwrap_or_else(|| {
            panic!(
                "fleet '{}' not in registry (known: {})",
                cell.fleet,
                self.fleet_registry.names().join(", ")
            )
        });
        let fault_spec = fault_spec_from_name(&cell.fault).unwrap_or_else(|| {
            panic!(
                "fault preset '{}' not in registry (known: {})",
                cell.fault,
                fault_name_menu()
            )
        });
        // Lookup is case-insensitive; the *result* always keys on the
        // canonical registry names so summaries, ratios, and the policy's
        // self-reported name agree regardless of the caller's casing.
        let canonical = ScenarioCell {
            platform: spec.name.clone(),
            preset: cell.preset,
            seed: cell.seed,
            fleet: fleet.name.clone(),
            fault: cell.fault.to_ascii_lowercase(),
        };
        let mut sim_cfg = SimConfig::for_experiment(self.gpus, cell.seed, spec.billing)
            .with_fleet(fleet.classes_for(self.gpus));
        // The cold-start-storm preset is the pod-lifecycle probe: the fleet
        // starts empty (no warm bootstrap), cold loads and host↔device
        // swaps take real time, and the cell reports TTFT percentiles.
        // Every other preset keeps the zero-latency default PerfModel and
        // warm start, so pre-existing cells keep their exact bytes.
        let perf = if cell.preset == Preset::ColdStartStorm {
            sim_cfg.warm_start = false;
            sim_cfg.lifecycle = true;
            PerfModel::with_swap_tier()
        } else {
            PerfModel::default()
        };
        // The pipeline presets activate the workflow subsystem: the cell's
        // function set becomes the workflow's stage functions (per-stage
        // SLOs from the e2e budget split), traffic enters only at the
        // entry stage, and the sim routes completions stage-to-stage.
        // The trace presets swap the synthetic zoo grid for a sampled
        // Azure-style population (heavy-tail popularity, mostly-idle
        // functions): the cluster starts cold and the active-set planner
        // runs with a lazy idle sweep — the knobs that make the 100k-
        // function cell feasible. Every other preset keeps the stock zoo
        // set and an empty workflow config, so pre-existing cells keep
        // their exact bytes.
        let workflow = pipeline_workflow(cell.preset);
        let (fns, trace) = if let Some(src) =
            crate::workload::TraceSource::for_preset(cell.preset, cell.seed, self.seconds, self.rps)
        {
            sim_cfg.warm_start = false;
            sim_cfg.idle_sweep = 8;
            src.sample(&perf)
        } else {
            let fns = match &workflow {
                Some(wf) => wf.stage_functions(&perf),
                None => experiment_functions(),
            };
            let names: Vec<&str> = match &workflow {
                Some(wf) => vec![fns[wf.entry()].name.as_str()],
                None => fns.iter().map(|f| f.name.as_str()).collect(),
            };
            let trace = TraceGen::preset(cell.preset, cell.seed, self.seconds, self.rps)
                .generate(&names);
            (fns, trace)
        };
        if let Some(wf) = &workflow {
            sim_cfg.workflows = vec![wf.clone()];
        }
        // The default spec is inert (zero fault events scheduled, no RNG
        // consumed), so `no-faults` cells keep their exact pre-fault bytes.
        sim_cfg.faults = fault_spec;
        let predictor = spec.build_predictor();
        let mut policy = spec.policy();
        // Every cell runs through the fleet-built cluster — for the default
        // uniform-v100 fleet this is the homogeneous construction to the
        // bit (pinned by tests/expt_golden.rs and the sim identity test).
        let report = run_sim(
            policy.as_mut(),
            &fns,
            &trace,
            predictor.as_ref(),
            &perf,
            &sim_cfg,
        );
        let result = CellResult::from_report(&canonical, &fns, &report);
        (report, result)
    }

    /// Run the whole grid, sharding cells across `jobs` worker threads
    /// (`0` = available parallelism). Results land in per-cell slots, so
    /// the aggregate is identical for every `jobs` value.
    pub fn run(&self, jobs: usize) -> MatrixReport {
        let cells = self.cells();
        let jobs = if jobs == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            jobs
        };
        let slots: Vec<Mutex<Option<CellResult>>> =
            cells.iter().map(|_| Mutex::new(None)).collect();
        ThreadPool::scope_for(jobs, cells.len(), |i| {
            let (_report, result) = self.run_cell(&cells[i]);
            *slots[i].lock().unwrap() = Some(result);
        });
        let results = slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("cell executed"))
            .collect();
        MatrixReport {
            seconds: self.seconds,
            gpus: self.gpus,
            rps: self.rps,
            fleets: self.fleets.clone(),
            faults: self.faults.clone(),
            cells: results,
        }
    }
}

/// Parse a fault-preset selection (one `--faults` list entry per element):
/// names from the fault-preset registry, case-insensitive, deduplicated in
/// first-appearance order. Unknown names error with the registry menu.
pub fn parse_faults(specs: &[String]) -> anyhow::Result<Vec<String>> {
    anyhow::ensure!(!specs.is_empty(), "need at least one fault preset");
    let mut out: Vec<String> = Vec::new();
    for s in specs {
        let t = s.trim().to_ascii_lowercase();
        anyhow::ensure!(
            fault_spec_from_name(&t).is_some(),
            "unknown fault preset '{}' (expected one of: {})",
            s.trim(),
            fault_name_menu()
        );
        if !out.contains(&t) {
            out.push(t);
        }
    }
    Ok(out)
}

/// Parse a fleet selection (one `--fleets` list entry per element) against
/// the fleet registry: names only, case-insensitive, deduplicated in
/// first-appearance order. Unknown names error with the full registry menu.
pub fn parse_fleets(specs: &[String], registry: &FleetRegistry) -> anyhow::Result<Vec<String>> {
    registry.resolve(specs)
}

/// Parse a seed specification: a bare count `"4"` expands to
/// `base..base+4`; a comma list `"3,17,99"` is taken verbatim.
pub fn parse_seeds(spec: &str, base: u64) -> anyhow::Result<Vec<u64>> {
    let parse_one = |s: &str| -> anyhow::Result<u64> {
        s.trim()
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad seed '{s}'"))
    };
    if spec.contains(',') {
        let seeds: Vec<u64> = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(parse_one)
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!seeds.is_empty(), "need at least one seed");
        return Ok(seeds);
    }
    let n = parse_one(spec)?;
    anyhow::ensure!(n > 0, "need at least one seed");
    Ok((0..n).map(|i| base + i).collect())
}

/// Parse a platform selection (one `--platforms` list entry per element)
/// against the registry: names and the group tokens `all` (stock trio) /
/// `ablations`, case-insensitive, deduplicated in first-appearance order.
/// Unknown names error with the full registry menu.
pub fn parse_platforms(
    specs: &[String],
    registry: &PlatformRegistry,
) -> anyhow::Result<Vec<String>> {
    registry.resolve(specs)
}

/// Parse a preset selection (one `--preset` list entry per element):
/// preset names and the `all` group token, case-insensitive, deduplicated
/// in first-appearance order.
pub fn parse_presets(specs: &[String]) -> anyhow::Result<Vec<Preset>> {
    anyhow::ensure!(!specs.is_empty(), "need at least one preset");
    let mut out: Vec<Preset> = Vec::new();
    let mut push = |p: Preset, out: &mut Vec<Preset>| {
        if !out.contains(&p) {
            out.push(p);
        }
    };
    for s in specs {
        let t = s.trim();
        if t.eq_ignore_ascii_case("all") {
            for p in ALL_PRESETS {
                push(p, &mut out);
            }
        } else if let Some(p) = Preset::from_name(t) {
            push(p, &mut out);
        } else {
            // The menu comes from the canonical PRESET_TABLE, so it can
            // never drift from what from_name accepts.
            anyhow::bail!(
                "unknown preset '{t}' (expected one of: {}, or 'all')",
                Preset::name_menu()
            );
        }
    }
    anyhow::ensure!(!out.is_empty(), "need at least one preset");
    Ok(out)
}

/// Per-function slice of one cell's result.
#[derive(Clone, Debug, PartialEq)]
pub struct FunctionCellMetrics {
    pub name: String,
    pub slo: f64,
    pub served: usize,
    pub dropped: usize,
    pub p50: f64,
    pub p99: f64,
    pub violation_rate: f64,
    pub cost: f64,
    pub gpu_seconds: f64,
    /// $ per 1000 served requests; `0.0` when nothing was served — the same
    /// convention as [`crate::metrics::CostMeter::cost_per_1k`], kept finite
    /// so the JSON export round-trips losslessly.
    pub cost_per_1k: f64,
}

impl FunctionCellMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("slo", Json::Num(self.slo)),
            ("served", Json::Num(self.served as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("p50", Json::Num(self.p50)),
            ("p99", Json::Num(self.p99)),
            ("violation_rate", Json::Num(self.violation_rate)),
            ("cost", Json::Num(self.cost)),
            ("gpu_seconds", Json::Num(self.gpu_seconds)),
            ("cost_per_1k", Json::Num(self.cost_per_1k)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(FunctionCellMetrics {
            name: j.get("name")?.as_str()?.to_string(),
            slo: j.get("slo")?.as_f64()?,
            served: j.get("served")?.as_usize()?,
            dropped: j.get("dropped")?.as_usize()?,
            p50: j.get("p50")?.as_f64()?,
            p99: j.get("p99")?.as_f64()?,
            violation_rate: j.get("violation_rate")?.as_f64()?,
            cost: j.get("cost")?.as_f64()?,
            gpu_seconds: j.get("gpu_seconds")?.as_f64()?,
            cost_per_1k: j.get("cost_per_1k")?.as_f64()?,
        })
    }
}

/// Per-GPU-class slice of one heterogeneous cell's result: the mixed-fleet
/// grid columns ($/1k per class, per-class occupancy). Only populated —
/// and only exported — for cells on non-reference fleets, so uniform-v100
/// grids keep their pre-fleet bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassCellMetrics {
    pub class: String,
    /// Devices of this class in the cell's fleet.
    pub gpus: usize,
    /// sm×quota-weighted GPU-seconds billed on this class.
    pub gpu_seconds: f64,
    /// $ billed on this class.
    pub cost: f64,
    /// Class $ per 1000 served requests (cell-wide served; `0.0` when
    /// nothing was served, the [`crate::metrics::CostMeter`] convention).
    pub cost_per_1k: f64,
    /// Mean billed occupancy of this class's devices over the run:
    /// gpu_seconds / (gpus × duration); `0.0` for an empty class.
    pub occupancy: f64,
}

impl ClassCellMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("class", Json::Str(self.class.clone())),
            ("gpus", Json::Num(self.gpus as f64)),
            ("gpu_seconds", Json::Num(self.gpu_seconds)),
            ("cost", Json::Num(self.cost)),
            ("cost_per_1k", Json::Num(self.cost_per_1k)),
            ("occupancy", Json::Num(self.occupancy)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(ClassCellMetrics {
            class: j.get("class")?.as_str()?.to_string(),
            gpus: j.get("gpus")?.as_usize()?,
            gpu_seconds: j.get("gpu_seconds")?.as_f64()?,
            cost: j.get("cost")?.as_f64()?,
            cost_per_1k: j.get("cost_per_1k")?.as_f64()?,
            occupancy: j.get("occupancy")?.as_f64()?,
        })
    }
}

/// Per-workflow slice of one pipeline cell's result: end-to-end latency
/// percentiles judged against the workflow's e2e SLO, plus what the whole
/// chain billed. Only populated — and only exported — for workflow-driven
/// cells, so single-function grids keep their pre-workflow bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkflowCellMetrics {
    pub name: String,
    pub e2e_slo: f64,
    /// Origins whose every stage completed (the last terminal closed them).
    pub served: usize,
    /// Origins lost anywhere along the chain (queue overflow, timeout,
    /// killed pod, end-of-run drain) — each counted exactly once.
    pub dropped: usize,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    /// Fraction of closed origins whose end-to-end latency missed the e2e
    /// deadline — a violation is an e2e miss, not any per-stage miss.
    pub e2e_violation_rate: f64,
    /// Σ stage-function cost: what the whole chain billed.
    pub cost: f64,
    /// Chain $ per 1000 completed workflows (`0.0` when none completed).
    pub cost_per_1k: f64,
}

impl WorkflowCellMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("e2e_slo", Json::Num(self.e2e_slo)),
            ("served", Json::Num(self.served as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("e2e_p50", Json::Num(self.e2e_p50)),
            ("e2e_p99", Json::Num(self.e2e_p99)),
            ("e2e_violation_rate", Json::Num(self.e2e_violation_rate)),
            ("cost", Json::Num(self.cost)),
            ("cost_per_1k", Json::Num(self.cost_per_1k)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(WorkflowCellMetrics {
            name: j.get("name")?.as_str()?.to_string(),
            e2e_slo: j.get("e2e_slo")?.as_f64()?,
            served: j.get("served")?.as_usize()?,
            dropped: j.get("dropped")?.as_usize()?,
            e2e_p50: j.get("e2e_p50")?.as_f64()?,
            e2e_p99: j.get("e2e_p99")?.as_f64()?,
            e2e_violation_rate: j.get("e2e_violation_rate")?.as_f64()?,
            cost: j.get("cost")?.as_f64()?,
            cost_per_1k: j.get("cost_per_1k")?.as_f64()?,
        })
    }
}

/// Aggregated metrics of one grid cell, keyed by registry platform name.
#[derive(Clone, Debug, PartialEq)]
pub struct CellResult {
    pub platform: String,
    /// Fleet the cell ran on; [`DEFAULT_FLEET`] cells omit the key in JSON.
    pub fleet: String,
    /// Fault preset of the cell; [`NO_FAULTS`] cells omit the key in JSON.
    pub fault: String,
    pub preset: Preset,
    pub seed: u64,
    pub served: usize,
    pub dropped: usize,
    /// Requests that died in a killed pod (in-flight at a GPU failure or
    /// pod crash). Only populated — and only exported — for fault-injected
    /// cells; `None` cells keep their pre-fault bytes.
    pub failed: Option<usize>,
    /// Fraction of fleet GPU-time the devices were up: only on
    /// fault-injected cells.
    pub availability: Option<f64>,
    /// Mean time-to-restore-capacity over every GPU/pod loss that a
    /// replacement replica closed; `None` when no loss was restored (or no
    /// faults ran).
    pub mttr: Option<f64>,
    /// Request-weighted violation rate, each function judged at its own SLO.
    pub slo_violation_rate: f64,
    /// P99 end-to-end latency merged across all functions (seconds; `0.0`
    /// when nothing was served).
    pub p99_latency: f64,
    /// Time-to-first-token P50/P99 (arrival → dispatch, seconds). Only
    /// populated — and only exported — for lifecycle-aware cells (the
    /// cold-start-storm preset); `None` cells keep their pre-lifecycle
    /// bytes. `Some(0.0)` when a lifecycle run served nothing.
    pub ttft_p50: Option<f64>,
    pub ttft_p99: Option<f64>,
    /// sm×quota-weighted GPU-seconds billed over the run.
    pub gpu_seconds: f64,
    pub total_cost: f64,
    /// $ per 1000 served requests across all functions (`0.0` if none).
    pub cost_per_1k: f64,
    pub vertical_ups: usize,
    pub vertical_downs: usize,
    pub horizontal_ups: usize,
    pub horizontal_downs: usize,
    pub functions: Vec<FunctionCellMetrics>,
    /// Per-class columns; empty (and unexported) on reference-uniform cells.
    pub classes: Vec<ClassCellMetrics>,
    /// Per-workflow e2e columns; empty (and unexported) on non-pipeline
    /// cells.
    pub workflows: Vec<WorkflowCellMetrics>,
}

impl CellResult {
    /// Distil one run's report into the grid row for its cell.
    pub fn from_report(cell: &ScenarioCell, fns: &[FunctionSpec], report: &RunReport) -> Self {
        let mut merged = report.merged_latency_summary();
        let p99_latency = if merged.is_empty() { 0.0 } else { merged.p99() };
        let (ttft_p50, ttft_p99) = if report.lifecycle {
            let mut t = report.merged_ttft_summary();
            if t.is_empty() {
                (Some(0.0), Some(0.0))
            } else {
                (Some(t.p50()), Some(t.p99()))
            }
        } else {
            (None, None)
        };
        let served = report.total_served();
        let slo_violation_rate =
            report.slo_violation_rate(fns.iter().map(|f| (f.name.as_str(), f.slo)));
        // Trace-preset cells carry only *touched* functions (the sampled
        // population is overwhelmingly idle; 100k all-zero rows would
        // swamp the export). Every other preset keeps one row per
        // function, zeros included — the historical shape, to the byte.
        let functions = fns
            .iter()
            .filter(|f| !cell.preset.is_trace() || report.functions.contains_key(&f.name))
            .map(|f| {
                let (srv, drp, p50, p99, violation_rate) = match report.functions.get(&f.name) {
                    Some(m) => {
                        let mut s = m.latency_summary();
                        let (p50, p99) = if s.is_empty() {
                            (0.0, 0.0)
                        } else {
                            (s.p50(), s.p99())
                        };
                        (m.served(), m.dropped(), p50, p99, m.violation_rate(f.slo))
                    }
                    None => (0, 0, 0.0, 0.0, 0.0),
                };
                let cost = report.costs.cost_of(&f.name);
                FunctionCellMetrics {
                    name: f.name.clone(),
                    slo: f.slo,
                    served: srv,
                    dropped: drp,
                    p50,
                    p99,
                    violation_rate,
                    cost,
                    gpu_seconds: report.costs.gpu_seconds_of(&f.name),
                    cost_per_1k: report.costs.cost_per_1k(&f.name, srv),
                }
            })
            .collect();
        // Per-class columns only for heterogeneous runs: a reference-uniform
        // fleet must produce the exact pre-fleet row.
        let heterogeneous = report
            .fleet_gpus
            .keys()
            .any(|c| c != crate::vgpu::REFERENCE_CLASS)
            || report.fleet_gpus.len() > 1;
        let classes = if heterogeneous {
            report
                .fleet_gpus
                .iter()
                .map(|(class, &gpus)| {
                    let gpu_seconds = report.costs.class_gpu_seconds_of(class);
                    let cost = report.costs.class_cost_of(class);
                    ClassCellMetrics {
                        class: class.clone(),
                        gpus,
                        gpu_seconds,
                        cost,
                        cost_per_1k: if served == 0 {
                            0.0
                        } else {
                            cost * 1000.0 / served as f64
                        },
                        occupancy: if gpus > 0 && report.duration > 0.0 {
                            gpu_seconds / (gpus as f64 * report.duration)
                        } else {
                            0.0
                        },
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        // Per-workflow e2e columns only for workflow-driven runs: the SLO
        // map is the gate, so a zero-traffic pipeline still gets its row.
        let empty = crate::metrics::FunctionMetrics::default();
        let workflows = report
            .workflow_slos
            .iter()
            .map(|(name, &slo)| {
                let m = report.workflow_e2e.get(name).unwrap_or(&empty);
                let mut lat = m.latency_summary();
                let (e2e_p50, e2e_p99) =
                    if lat.is_empty() { (0.0, 0.0) } else { (lat.p50(), lat.p99()) };
                let prefix = format!("{name}:");
                let cost: f64 = fns
                    .iter()
                    .filter(|f| f.name.starts_with(&prefix))
                    .map(|f| report.costs.cost_of(&f.name))
                    .sum();
                let wf_served = m.served();
                WorkflowCellMetrics {
                    name: name.clone(),
                    e2e_slo: slo,
                    served: wf_served,
                    dropped: m.dropped(),
                    e2e_p50,
                    e2e_p99,
                    e2e_violation_rate: m.violation_rate(slo),
                    cost,
                    cost_per_1k: if wf_served == 0 {
                        0.0
                    } else {
                        cost * 1000.0 / wf_served as f64
                    },
                }
            })
            .collect();
        let (failed, availability, mttr) = if report.faults_active {
            (
                Some(report.total_failed()),
                Some(report.availability()),
                report.mttr_mean(),
            )
        } else {
            (None, None, None)
        };
        CellResult {
            platform: cell.platform.clone(),
            fleet: cell.fleet.clone(),
            fault: cell.fault.clone(),
            preset: cell.preset,
            seed: cell.seed,
            served,
            dropped: report.total_dropped(),
            failed,
            availability,
            mttr,
            slo_violation_rate,
            p99_latency,
            ttft_p50,
            ttft_p99,
            gpu_seconds: report.costs.total_gpu_seconds(),
            total_cost: report.costs.total_cost(),
            cost_per_1k: if served == 0 {
                0.0
            } else {
                report.costs.total_cost() * 1000.0 / served as f64
            },
            vertical_ups: report.vertical_ups,
            vertical_downs: report.vertical_downs,
            horizontal_ups: report.horizontal_ups,
            horizontal_downs: report.horizontal_downs,
            functions,
            classes,
            workflows,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("platform", Json::Str(self.platform.clone()))];
        // Byte-stability rule: reference-uniform cells (the pre-fleet
        // schema) export no fleet/classes keys; everything else carries
        // both.
        if self.fleet != DEFAULT_FLEET {
            fields.push(("fleet", Json::Str(self.fleet.clone())));
        }
        // Same rule for the fault axis: `no-faults` cells carry no fault
        // keys at all — the pre-fault export to the byte.
        if self.fault != NO_FAULTS {
            fields.push(("fault", Json::Str(self.fault.clone())));
        }
        fields.extend([
            ("preset", Json::Str(self.preset.name().to_string())),
            ("seed", Json::Num(self.seed as f64)),
            ("served", Json::Num(self.served as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
        ]);
        if let Some(f) = self.failed {
            fields.push(("failed", Json::Num(f as f64)));
        }
        if let Some(a) = self.availability {
            fields.push(("availability", Json::Num(a)));
        }
        if let Some(m) = self.mttr {
            fields.push(("mttr", Json::Num(m)));
        }
        fields.extend([
            ("slo_violation_rate", Json::Num(self.slo_violation_rate)),
            ("p99_latency", Json::Num(self.p99_latency)),
        ]);
        // Same key-omission rule as fleet/classes: TTFT keys exist only on
        // lifecycle-aware cells, so pre-lifecycle grids keep their bytes.
        if let Some(t) = self.ttft_p50 {
            fields.push(("ttft_p50", Json::Num(t)));
        }
        if let Some(t) = self.ttft_p99 {
            fields.push(("ttft_p99", Json::Num(t)));
        }
        fields.extend([
            ("gpu_seconds", Json::Num(self.gpu_seconds)),
            ("total_cost", Json::Num(self.total_cost)),
            ("cost_per_1k", Json::Num(self.cost_per_1k)),
            ("vertical_ups", Json::Num(self.vertical_ups as f64)),
            ("vertical_downs", Json::Num(self.vertical_downs as f64)),
            ("horizontal_ups", Json::Num(self.horizontal_ups as f64)),
            ("horizontal_downs", Json::Num(self.horizontal_downs as f64)),
            ("functions", Json::Arr(self.functions.iter().map(|f| f.to_json()).collect())),
        ]);
        if !self.classes.is_empty() {
            fields.push((
                "classes",
                Json::Arr(self.classes.iter().map(|c| c.to_json()).collect()),
            ));
        }
        // Same key-omission rule again: only pipeline cells carry workflow
        // rows, so single-function grids keep their pre-workflow bytes.
        if !self.workflows.is_empty() {
            fields.push((
                "workflows",
                Json::Arr(self.workflows.iter().map(|w| w.to_json()).collect()),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        // Platform names are open registry keys, not a closed enum: any
        // non-empty name parses, so grids with ablation or caller-registered
        // platforms round-trip.
        let platform = j.get("platform")?.as_str()?.to_string();
        anyhow::ensure!(!platform.is_empty(), "cell platform name must be non-empty");
        let preset_name = j.get("preset")?.as_str()?;
        let preset = Preset::from_name(preset_name)
            .ok_or_else(|| anyhow::anyhow!("unknown preset '{preset_name}'"))?;
        // Absent fleet key ⇒ the pre-fleet schema ⇒ the default fleet.
        let fleet = match j.opt("fleet") {
            Some(v) => {
                let name = v.as_str()?.to_string();
                anyhow::ensure!(!name.is_empty(), "cell fleet name must be non-empty");
                name
            }
            None => DEFAULT_FLEET.to_string(),
        };
        // Absent fault key ⇒ the pre-fault schema ⇒ no faults.
        let fault = match j.opt("fault") {
            Some(v) => {
                let name = v.as_str()?.to_string();
                anyhow::ensure!(!name.is_empty(), "cell fault name must be non-empty");
                name
            }
            None => NO_FAULTS.to_string(),
        };
        let classes = match j.opt("classes") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(ClassCellMetrics::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        // Absent workflows key ⇒ a pre-workflow (or single-function) cell.
        let workflows = match j.opt("workflows") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(WorkflowCellMetrics::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(CellResult {
            platform,
            fleet,
            fault,
            preset,
            seed: j.get("seed")?.as_f64()? as u64,
            served: j.get("served")?.as_usize()?,
            dropped: j.get("dropped")?.as_usize()?,
            // Absent fault-metric keys ⇒ a pre-fault (or no-faults) cell.
            failed: j.opt("failed").map(|v| v.as_usize()).transpose()?,
            availability: j.opt("availability").map(|v| v.as_f64()).transpose()?,
            mttr: j.opt("mttr").map(|v| v.as_f64()).transpose()?,
            slo_violation_rate: j.get("slo_violation_rate")?.as_f64()?,
            p99_latency: j.get("p99_latency")?.as_f64()?,
            // Absent TTFT keys ⇒ a pre-lifecycle cell.
            ttft_p50: j.opt("ttft_p50").map(|v| v.as_f64()).transpose()?,
            ttft_p99: j.opt("ttft_p99").map(|v| v.as_f64()).transpose()?,
            gpu_seconds: j.get("gpu_seconds")?.as_f64()?,
            total_cost: j.get("total_cost")?.as_f64()?,
            cost_per_1k: j.get("cost_per_1k")?.as_f64()?,
            vertical_ups: j.get("vertical_ups")?.as_usize()?,
            vertical_downs: j.get("vertical_downs")?.as_usize()?,
            horizontal_ups: j.get("horizontal_ups")?.as_usize()?,
            horizontal_downs: j.get("horizontal_downs")?.as_usize()?,
            functions: j
                .get("functions")?
                .as_arr()?
                .iter()
                .map(FunctionCellMetrics::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            classes,
            workflows,
        })
    }
}

/// One aggregated row of the comparison table: a (preset, fleet, platform)
/// group averaged over its seeds.
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryRow {
    pub preset: Preset,
    /// Fleet of the group ([`DEFAULT_FLEET`] rows omit the key in JSON).
    pub fleet: String,
    /// Fault preset of the group ([`NO_FAULTS`] rows omit the key in JSON).
    pub fault: String,
    pub platform: String,
    pub cells: usize,
    pub slo_violation_rate: f64,
    pub p99_latency: f64,
    /// Mean availability / MTTR over the group's fault-injected cells;
    /// `None` when the group has none (pre-fault rows keep their bytes).
    pub availability: Option<f64>,
    pub mttr: Option<f64>,
    /// Mean TTFT percentiles over the group's lifecycle-aware cells;
    /// `None` when the group has none (pre-lifecycle rows keep their
    /// bytes — the keys are omitted from the JSON summary).
    pub ttft_p50: Option<f64>,
    pub ttft_p99: Option<f64>,
    /// Mean workflow e2e P99 / chain $ per 1k completed workflows over the
    /// group's pipeline cells; `None` when the group has none
    /// (pre-workflow rows keep their bytes — the keys are omitted).
    pub e2e_p99: Option<f64>,
    pub e2e_cost_per_1k: Option<f64>,
    pub gpu_seconds: f64,
    pub cost_per_1k: f64,
}

/// The paper's headline comparison for one (preset, fleet, baseline) pair:
/// baseline ÷ HAS-GPU ratios, seeds averaged first, always within one
/// fleet (cross-fleet ratios would compare different hardware). A ratio is
/// `None` when HAS-GPU's own mean is zero (the ratio is undefined, not
/// huge). Ablation platforms get ratio rows too — that is the
/// hybrid-vs-single-axis table.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadlineRatio {
    pub preset: Preset,
    pub fleet: String,
    /// Fault preset of the pair ([`NO_FAULTS`] rows omit the key in JSON).
    pub fault: String,
    pub platform: String,
    /// baseline $/1k over HAS-GPU $/1k (paper: 10.8x for KServe).
    pub cost_ratio: Option<f64>,
    /// baseline violation rate over HAS-GPU's (paper: 4.8x for FaST-GShare).
    pub violation_ratio: Option<f64>,
    /// baseline TTFT P99 over HAS-GPU's. `None` unless both rows carry
    /// TTFT (lifecycle presets) with a positive denominator — and then
    /// the key is omitted from JSON entirely, keeping pre-lifecycle
    /// ratio rows byte-identical.
    pub ttft_ratio: Option<f64>,
    /// baseline mean-time-to-restore over HAS-GPU's — the chaos headline
    /// (has-gpu replaces lost replicas next tick; kserve waits out a full
    /// instance cold start). Same key-omission rule as `ttft_ratio`.
    pub mttr_ratio: Option<f64>,
    /// baseline workflow e2e P99 over HAS-GPU's — the pipeline headline
    /// (co-scaled stages keep the chain's tail inside the e2e budget).
    /// Same key-omission rule as `ttft_ratio`.
    pub e2e_ratio: Option<f64>,
}

/// Everything one `has-gpu expt` invocation produces: config echo, per-cell
/// results, and the derived summary. Serialises to `BENCH_sim.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixReport {
    pub seconds: usize,
    pub gpus: usize,
    pub rps: f64,
    /// Fleet names of the grid, in cell-column order. `[uniform-v100]`
    /// (the default) is omitted from the config echo for byte-stability.
    pub fleets: Vec<String>,
    /// Fault preset names of the grid, in cell-column order. `[no-faults]`
    /// (the default) is omitted from the config echo for byte-stability.
    pub faults: Vec<String>,
    pub cells: Vec<CellResult>,
}

pub const BENCH_SIM_SCHEMA: &str = "has-gpu/bench-sim/v1";

impl MatrixReport {
    /// Seed-averaged rows per (preset, fault, fleet, platform), in
    /// first-appearance order (which is the canonical cell order when
    /// produced by `run`).
    pub fn summary(&self) -> Vec<SummaryRow> {
        let mut order: Vec<(Preset, &str, &str, &str)> = Vec::new();
        for c in &self.cells {
            let key = (c.preset, c.fault.as_str(), c.fleet.as_str(), c.platform.as_str());
            if !order.contains(&key) {
                order.push(key);
            }
        }
        order
            .into_iter()
            .map(|(preset, fault, fleet, platform)| {
                let group: Vec<&CellResult> = self
                    .cells
                    .iter()
                    .filter(|c| {
                        c.preset == preset
                            && c.fault == fault
                            && c.fleet == fleet
                            && c.platform == platform
                    })
                    .collect();
                let n = group.len() as f64;
                // TTFT averages over the cells that carry it (lifecycle
                // runs); a group with none stays `None`.
                let mean_opt = |vals: Vec<f64>| {
                    if vals.is_empty() {
                        None
                    } else {
                        Some(vals.iter().sum::<f64>() / vals.len() as f64)
                    }
                };
                // Workflow columns average first within a cell (over its
                // workflows), then across the group's pipeline cells.
                let wf_mean = |c: &CellResult, f: fn(&WorkflowCellMetrics) -> f64| {
                    if c.workflows.is_empty() {
                        None
                    } else {
                        let sum: f64 = c.workflows.iter().map(f).sum();
                        Some(sum / c.workflows.len() as f64)
                    }
                };
                SummaryRow {
                    preset,
                    fleet: fleet.to_string(),
                    fault: fault.to_string(),
                    platform: platform.to_string(),
                    cells: group.len(),
                    slo_violation_rate: group.iter().map(|c| c.slo_violation_rate).sum::<f64>()
                        / n,
                    p99_latency: group.iter().map(|c| c.p99_latency).sum::<f64>() / n,
                    availability: mean_opt(
                        group.iter().filter_map(|c| c.availability).collect(),
                    ),
                    mttr: mean_opt(group.iter().filter_map(|c| c.mttr).collect()),
                    ttft_p50: mean_opt(group.iter().filter_map(|c| c.ttft_p50).collect()),
                    ttft_p99: mean_opt(group.iter().filter_map(|c| c.ttft_p99).collect()),
                    e2e_p99: mean_opt(
                        group.iter().filter_map(|c| wf_mean(c, |w| w.e2e_p99)).collect(),
                    ),
                    e2e_cost_per_1k: mean_opt(
                        group.iter().filter_map(|c| wf_mean(c, |w| w.cost_per_1k)).collect(),
                    ),
                    gpu_seconds: group.iter().map(|c| c.gpu_seconds).sum::<f64>() / n,
                    cost_per_1k: group.iter().map(|c| c.cost_per_1k).sum::<f64>() / n,
                }
            })
            .collect()
    }

    /// Baseline ÷ HAS-GPU ratios per (preset, fault, fleet) — cross-fleet
    /// or cross-fault ratios would compare different hardware or different
    /// luck. A zero HAS-GPU denominator yields `None` (undefined) rather
    /// than an absurd finite number.
    pub fn ratios_vs_has_gpu(&self) -> Vec<HeadlineRatio> {
        let summary = self.summary();
        let ratio = |num: f64, den: f64| if den > 0.0 { Some(num / den) } else { None };
        let mut out = Vec::new();
        for row in &summary {
            if row.platform == HAS_GPU {
                continue;
            }
            let Some(has) = summary.iter().find(|r| {
                r.preset == row.preset
                    && r.fault == row.fault
                    && r.fleet == row.fleet
                    && r.platform == HAS_GPU
            }) else {
                continue;
            };
            let opt_ratio = |num: Option<f64>, den: Option<f64>| match (num, den) {
                (Some(num), Some(den)) => ratio(num, den),
                _ => None,
            };
            out.push(HeadlineRatio {
                preset: row.preset,
                fleet: row.fleet.clone(),
                fault: row.fault.clone(),
                platform: row.platform.clone(),
                cost_ratio: ratio(row.cost_per_1k, has.cost_per_1k),
                violation_ratio: ratio(row.slo_violation_rate, has.slo_violation_rate),
                ttft_ratio: opt_ratio(row.ttft_p99, has.ttft_p99),
                mttr_ratio: opt_ratio(row.mttr, has.mttr),
                e2e_ratio: opt_ratio(row.e2e_p99, has.e2e_p99),
            });
        }
        out
    }

    /// Does this grid contain any non-default-fleet cells (⇒ the export
    /// carries fleet keys and the table a fleet column)?
    fn has_fleet_cells(&self) -> bool {
        self.cells.iter().any(|c| c.fleet != DEFAULT_FLEET)
    }

    /// Does this grid contain any fault-injected cells (⇒ the export
    /// carries fault keys and the table fault/availability/MTTR columns)?
    fn has_fault_cells(&self) -> bool {
        self.cells.iter().any(|c| c.fault != NO_FAULTS)
    }

    /// The paper-style comparison table, rendered as ASCII. Grids with a
    /// non-default fleet gain a `fleet` column, chaos grids gain
    /// fault/availability/MTTR columns; stock grids keep the familiar
    /// shape.
    pub fn table(&self) -> String {
        let with_fleet = self.has_fleet_cells();
        let with_faults = self.has_fault_cells();
        let summary = self.summary();
        // TTFT columns appear only when some row actually carries TTFT
        // (lifecycle presets) — stock grids keep the familiar shape. The
        // workflow e2e columns follow the same rule for pipeline presets.
        let with_ttft = summary.iter().any(|r| r.ttft_p99.is_some());
        let with_wf = summary.iter().any(|r| r.e2e_p99.is_some());
        let fmt_opt = |v: Option<f64>| match v {
            Some(t) => format!("{:.1}", t * 1e3),
            None => "-".to_string(),
        };
        let rows: Vec<Vec<String>> = summary
            .iter()
            .map(|r| {
                let mut row = vec![r.preset.name().to_string()];
                if with_fleet {
                    row.push(r.fleet.clone());
                }
                if with_faults {
                    row.push(r.fault.clone());
                }
                row.extend([
                    r.platform.clone(),
                    format!("{}", r.cells),
                    format!("{:.4}", r.slo_violation_rate),
                    format!("{:.1}", r.p99_latency * 1e3),
                ]);
                if with_faults {
                    row.push(match r.availability {
                        Some(a) => format!("{a:.4}"),
                        None => "-".to_string(),
                    });
                    row.push(match r.mttr {
                        Some(m) => format!("{m:.1}"),
                        None => "-".to_string(),
                    });
                }
                if with_ttft {
                    row.push(fmt_opt(r.ttft_p50));
                    row.push(fmt_opt(r.ttft_p99));
                }
                if with_wf {
                    row.push(fmt_opt(r.e2e_p99));
                    row.push(match r.e2e_cost_per_1k {
                        Some(c) => format!("{c:.4}"),
                        None => "-".to_string(),
                    });
                }
                row.extend([
                    format!("{:.1}", r.gpu_seconds),
                    format!("{:.4}", r.cost_per_1k),
                ]);
                row
            })
            .collect();
        let mut headers = vec!["preset"];
        if with_fleet {
            headers.push("fleet");
        }
        if with_faults {
            headers.push("fault");
        }
        headers.extend(["platform", "seeds", "slo-viol", "p99 (ms)"]);
        if with_faults {
            headers.extend(["avail", "mttr (s)"]);
        }
        if with_ttft {
            headers.extend(["ttft-p50 (ms)", "ttft-p99 (ms)"]);
        }
        if with_wf {
            headers.extend(["e2e-p99 (ms)", "wf-$/1k"]);
        }
        headers.extend(["gpu-sec", "$/1k"]);
        ascii_table(&headers, &rows)
    }

    pub fn to_json(&self) -> Json {
        let summary = Json::Arr(
            self.summary()
                .iter()
                .map(|r| {
                    let mut fields = vec![("preset", Json::Str(r.preset.name().to_string()))];
                    if r.fleet != DEFAULT_FLEET {
                        fields.push(("fleet", Json::Str(r.fleet.clone())));
                    }
                    if r.fault != NO_FAULTS {
                        fields.push(("fault", Json::Str(r.fault.clone())));
                    }
                    fields.extend([
                        ("platform", Json::Str(r.platform.clone())),
                        ("cells", Json::Num(r.cells as f64)),
                        ("slo_violation_rate", Json::Num(r.slo_violation_rate)),
                        ("p99_latency", Json::Num(r.p99_latency)),
                    ]);
                    // Key omission mirrors the cell rule: only fault rows
                    // export availability/MTTR, only lifecycle rows TTFT.
                    if let Some(a) = r.availability {
                        fields.push(("availability", Json::Num(a)));
                    }
                    if let Some(m) = r.mttr {
                        fields.push(("mttr", Json::Num(m)));
                    }
                    if let Some(t) = r.ttft_p50 {
                        fields.push(("ttft_p50", Json::Num(t)));
                    }
                    if let Some(t) = r.ttft_p99 {
                        fields.push(("ttft_p99", Json::Num(t)));
                    }
                    if let Some(t) = r.e2e_p99 {
                        fields.push(("e2e_p99", Json::Num(t)));
                    }
                    if let Some(c) = r.e2e_cost_per_1k {
                        fields.push(("e2e_cost_per_1k", Json::Num(c)));
                    }
                    fields.extend([
                        ("gpu_seconds", Json::Num(r.gpu_seconds)),
                        ("cost_per_1k", Json::Num(r.cost_per_1k)),
                    ]);
                    Json::obj(fields)
                })
                .collect(),
        );
        let opt_num = |v: Option<f64>| v.map_or(Json::Null, Json::Num);
        let ratios = Json::Arr(
            self.ratios_vs_has_gpu()
                .iter()
                .map(|r| {
                    let mut fields = vec![("preset", Json::Str(r.preset.name().to_string()))];
                    if r.fleet != DEFAULT_FLEET {
                        fields.push(("fleet", Json::Str(r.fleet.clone())));
                    }
                    if r.fault != NO_FAULTS {
                        fields.push(("fault", Json::Str(r.fault.clone())));
                    }
                    fields.extend([
                        ("platform", Json::Str(r.platform.clone())),
                        ("cost_ratio", opt_num(r.cost_ratio)),
                        ("violation_ratio", opt_num(r.violation_ratio)),
                    ]);
                    // Unlike cost/violation (whose None means "undefined
                    // for this grid"), an absent ttft_ratio/mttr_ratio
                    // means the metric doesn't exist for the preset — omit
                    // the key so pre-lifecycle/pre-fault ratio rows keep
                    // their bytes.
                    if let Some(t) = r.ttft_ratio {
                        fields.push(("ttft_ratio", Json::Num(t)));
                    }
                    if let Some(m) = r.mttr_ratio {
                        fields.push(("mttr_ratio", Json::Num(m)));
                    }
                    if let Some(e) = r.e2e_ratio {
                        fields.push(("e2e_ratio", Json::Num(e)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        let mut config = vec![
            ("seconds", Json::Num(self.seconds as f64)),
            ("gpus", Json::Num(self.gpus as f64)),
            ("rps", Json::Num(self.rps)),
        ];
        // Config echoes the fleet/fault axes only when they depart from
        // the pre-fleet/pre-fault defaults (byte-stability of stock grids).
        if self.fleets != [DEFAULT_FLEET.to_string()] {
            config.push((
                "fleets",
                Json::Arr(self.fleets.iter().map(|f| Json::Str(f.clone())).collect()),
            ));
        }
        if self.faults != [NO_FAULTS.to_string()] {
            config.push((
                "faults",
                Json::Arr(self.faults.iter().map(|f| Json::Str(f.clone())).collect()),
            ));
        }
        Json::obj(vec![
            ("schema", Json::Str(BENCH_SIM_SCHEMA.to_string())),
            ("config", Json::obj(config)),
            ("cells", Json::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
            ("summary", summary),
            ("ratios_vs_has_gpu", ratios),
        ])
    }

    /// Load a report back from its JSON form. `summary` and
    /// `ratios_vs_has_gpu` are derived, so only config + cells are read;
    /// re-serialising the result reproduces the input byte-for-byte.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let schema = j.get("schema")?.as_str()?;
        anyhow::ensure!(
            schema == BENCH_SIM_SCHEMA,
            "unsupported BENCH_sim schema '{schema}' (expected '{BENCH_SIM_SCHEMA}')"
        );
        let config = j.get("config")?;
        let fleets = match config.opt("fleets") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|f| Ok(f.as_str()?.to_string()))
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![DEFAULT_FLEET.to_string()],
        };
        let faults = match config.opt("faults") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|f| Ok(f.as_str()?.to_string()))
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![NO_FAULTS.to_string()],
        };
        Ok(MatrixReport {
            seconds: config.get("seconds")?.as_usize()?,
            gpus: config.get("gpus")?.as_usize()?,
            rps: config.get("rps")?.as_f64()?,
            fleets,
            faults,
            cells: j
                .get("cells")?
                .as_arr()?
                .iter()
                .map(CellResult::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn trio() -> Vec<String> {
        strs(&["has-gpu", "kserve", "fast-gshare"])
    }

    #[test]
    fn registry_names_resolve_and_match_policies() {
        let reg = PlatformRegistry::default();
        for spec in reg.specs() {
            assert_eq!(reg.get(&spec.name).unwrap().name, spec.name);
            // The policy self-reports the same platform name the matrix uses.
            assert_eq!(spec.policy().name(), spec.name);
        }
        assert!(reg.get("nope").is_none());
        assert_eq!(
            reg.get("kserve").unwrap().billing,
            crate::metrics::BillingMode::WholeGpu
        );
        assert_eq!(
            reg.get("has-gpu").unwrap().billing,
            crate::metrics::BillingMode::FineGrained
        );
    }

    #[test]
    fn cells_enumerate_in_canonical_order() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu", "kserve"]),
            presets: vec![Preset::Standard, Preset::Stress],
            seeds: vec![1, 2],
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        assert_eq!(cells.len(), 8);
        // Preset-major, then platform, then seed.
        assert_eq!(cells[0].preset, Preset::Standard);
        assert_eq!(cells[0].platform, "has-gpu");
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].seed, 2);
        assert_eq!(cells[2].platform, "kserve");
        assert_eq!(cells[4].preset, Preset::Stress);
    }

    #[test]
    fn fleet_axis_enumerates_between_preset_and_platform() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu", "kserve"]),
            presets: vec![Preset::Standard],
            seeds: vec![1, 2],
            fleets: strs(&["uniform-v100", "mixed-a100-v100-t4"]),
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        assert_eq!(cells.len(), 8);
        // fleet-major inside each preset: all uniform cells first.
        assert!(cells[..4].iter().all(|c| c.fleet == DEFAULT_FLEET));
        assert!(cells[4..].iter().all(|c| c.fleet == "mixed-a100-v100-t4"));
        assert_eq!(cells[4].platform, "has-gpu");
        assert_eq!(cells[6].platform, "kserve");
    }

    #[test]
    fn uniform_cells_export_no_fleet_keys_and_mixed_cells_do() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu"]),
            presets: vec![Preset::Standard],
            seeds: vec![3],
            seconds: 30,
            gpus: 4,
            rps: 20.0,
            fleets: strs(&["uniform-v100", "mixed-a100-v100-t4"]),
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        let (_r0, uniform) = m.run_cell(&cells[0]);
        let (_r1, mixed) = m.run_cell(&cells[1]);
        // Uniform: pre-fleet schema — no fleet, no classes.
        assert!(uniform.classes.is_empty());
        assert!(uniform.to_json().opt("fleet").is_none());
        assert!(uniform.to_json().opt("classes").is_none());
        // Mixed: fleet key + one class row per catalog class in the fleet.
        assert_eq!(mixed.fleet, "mixed-a100-v100-t4");
        assert_eq!(
            mixed.to_json().opt("fleet").and_then(|v| v.as_str().ok()),
            Some("mixed-a100-v100-t4")
        );
        assert_eq!(mixed.classes.len(), 3, "{:?}", mixed.classes);
        let class_cost: f64 = mixed.classes.iter().map(|c| c.cost).sum();
        assert!((class_cost - mixed.total_cost).abs() < 1e-9);
        let gpus: usize = mixed.classes.iter().map(|c| c.gpus).sum();
        assert_eq!(gpus, 4);
        for c in &mixed.classes {
            assert!((0.0..=1.0 + 1e-9).contains(&c.occupancy), "{c:?}");
        }
        // Mixed cells round-trip through JSON losslessly.
        let back = CellResult::from_json(&mixed.to_json()).unwrap();
        assert_eq!(back, mixed);
        assert_eq!(
            back.to_json().to_string_pretty(),
            mixed.to_json().to_string_pretty()
        );
    }

    #[test]
    fn mixed_fleet_report_groups_summary_and_ratios_per_fleet() {
        let mut cells = vec![
            mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
            mk_cell("kserve", Preset::Standard, 1, 0.05, 10.0),
        ];
        let mut mixed_has = mk_cell("has-gpu", Preset::Standard, 1, 0.02, 2.0);
        mixed_has.fleet = "mixed-a100-v100-t4".into();
        let mut mixed_ks = mk_cell("kserve", Preset::Standard, 1, 0.06, 30.0);
        mixed_ks.fleet = "mixed-a100-v100-t4".into();
        cells.push(mixed_has);
        cells.push(mixed_ks);
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: strs(&["uniform-v100", "mixed-a100-v100-t4"]),
            faults: vec![NO_FAULTS.to_string()],
            cells,
        };
        let summary = report.summary();
        assert_eq!(summary.len(), 4);
        // Ratios pair baselines with HAS-GPU *within* each fleet.
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 2);
        assert_eq!(ratios[0].fleet, DEFAULT_FLEET);
        assert!((ratios[0].cost_ratio.unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(ratios[1].fleet, "mixed-a100-v100-t4");
        assert!((ratios[1].cost_ratio.unwrap() - 15.0).abs() < 1e-9);
        // The table gains a fleet column only for fleet grids.
        assert!(report.table().contains("fleet"));
        assert!(report.table().contains("mixed-a100-v100-t4"));
        // And the whole report round-trips.
        let back = MatrixReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);
        assert_eq!(
            back.to_json().to_string_pretty(),
            report.to_json().to_string_pretty()
        );
    }

    #[test]
    fn seed_spec_parsing() {
        assert_eq!(parse_seeds("3", 11).unwrap(), vec![11, 12, 13]);
        assert_eq!(parse_seeds("4,8,15", 0).unwrap(), vec![4, 8, 15]);
        assert_eq!(parse_seeds("5,", 0).unwrap(), vec![5]);
        assert!(parse_seeds("0", 11).is_err());
        assert!(parse_seeds("x", 11).is_err());
        assert!(parse_seeds(",", 11).is_err(), "all-empty list must not run 0 cells");
    }

    #[test]
    fn platform_and_preset_spec_parsing() {
        let reg = PlatformRegistry::default();
        assert_eq!(parse_platforms(&strs(&["all"]), &reg).unwrap(), trio());
        assert_eq!(
            parse_platforms(&strs(&["kserve", "has-gpu"]), &reg).unwrap(),
            strs(&["kserve", "has-gpu"])
        );
        // Case-insensitive, and groups compose.
        assert_eq!(
            parse_platforms(&strs(&["KServe"]), &reg).unwrap(),
            strs(&["kserve"])
        );
        assert_eq!(
            parse_platforms(&strs(&["all", "ablations"]), &reg).unwrap().len(),
            6
        );
        // Unknown names list the registry.
        let err = parse_platforms(&strs(&["gke"]), &reg).unwrap_err().to_string();
        assert!(err.contains("fast-gshare") && err.contains("has-vertical-only"), "{err}");
        assert!(parse_platforms(&[], &reg).is_err());

        assert_eq!(parse_presets(&strs(&["all"])).unwrap(), ALL_PRESETS.to_vec());
        assert_eq!(
            parse_presets(&strs(&["diurnal", "spiky-burst"])).unwrap(),
            vec![Preset::Diurnal, Preset::SpikyBurst]
        );
        assert_eq!(
            parse_presets(&strs(&["STANDARD"])).unwrap(),
            vec![Preset::Standard],
            "preset names are case-insensitive"
        );
        let err = parse_presets(&strs(&["weekend"])).unwrap_err().to_string();
        assert!(err.contains("standard") && err.contains("spiky-burst"), "{err}");
        assert!(parse_presets(&[]).is_err());
    }

    #[test]
    fn single_cell_run_populates_metrics() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu"]),
            presets: vec![Preset::Standard],
            seeds: vec![7],
            seconds: 60,
            gpus: 6,
            rps: 60.0,
            ..ScenarioMatrix::default()
        };
        let cell = m.cells()[0].clone();
        let (report, result) = m.run_cell(&cell);
        assert_eq!(result.platform, "has-gpu");
        assert_eq!(result.seed, 7);
        assert!(result.served > 100, "served {}", result.served);
        assert_eq!(result.served, report.total_served());
        assert!(result.total_cost > 0.0);
        assert!(result.gpu_seconds > 0.0);
        assert!(result.p99_latency > 0.0 && result.p99_latency.is_finite());
        assert!((0.0..=1.0).contains(&result.slo_violation_rate));
        // Per-function rows cover the whole experiment set and sum to totals.
        assert_eq!(result.functions.len(), experiment_functions().len());
        let fn_served: usize = result.functions.iter().map(|f| f.served).sum();
        assert_eq!(fn_served, result.served);
    }

    #[test]
    fn run_cell_canonicalizes_the_platform_name() {
        // The matrix fields are pub, so a caller can bypass parse_platforms
        // with non-canonical casing; the result must still key on the
        // registry name or summaries/ratios would split on case.
        let m = ScenarioMatrix {
            platforms: strs(&["HAS-GPU"]),
            presets: vec![Preset::Standard],
            seeds: vec![2],
            seconds: 30,
            gpus: 4,
            rps: 20.0,
            ..ScenarioMatrix::default()
        };
        let cell = m.cells()[0].clone();
        assert_eq!(cell.platform, "HAS-GPU");
        let (report, result) = m.run_cell(&cell);
        assert_eq!(result.platform, "has-gpu");
        assert_eq!(report.platform, "has-gpu");
    }

    #[test]
    #[should_panic(expected = "not in registry")]
    fn run_cell_panics_on_unregistered_platform() {
        let m = ScenarioMatrix::default();
        let cell = ScenarioCell {
            platform: "not-a-platform".into(),
            preset: Preset::Standard,
            seed: 1,
            fleet: DEFAULT_FLEET.into(),
            fault: NO_FAULTS.into(),
        };
        let _ = m.run_cell(&cell);
    }

    fn mk_cell(
        platform: &str,
        preset: Preset,
        seed: u64,
        viol: f64,
        cost_per_1k: f64,
    ) -> CellResult {
        CellResult {
            platform: platform.to_string(),
            fleet: DEFAULT_FLEET.to_string(),
            fault: NO_FAULTS.to_string(),
            preset,
            seed,
            served: 1000,
            dropped: 0,
            failed: None,
            availability: None,
            mttr: None,
            slo_violation_rate: viol,
            p99_latency: 0.1,
            ttft_p50: None,
            ttft_p99: None,
            gpu_seconds: 50.0,
            total_cost: cost_per_1k,
            cost_per_1k,
            vertical_ups: 0,
            vertical_downs: 0,
            horizontal_ups: 0,
            horizontal_downs: 0,
            functions: Vec::new(),
            classes: Vec::new(),
            workflows: Vec::new(),
        }
    }

    #[test]
    fn summary_and_ratios_from_synthetic_cells() {
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![
                mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
                mk_cell("has-gpu", Preset::Standard, 2, 0.03, 3.0),
                mk_cell("kserve", Preset::Standard, 1, 0.10, 20.0),
                mk_cell("kserve", Preset::Standard, 2, 0.10, 24.0),
            ],
        };
        let summary = report.summary();
        assert_eq!(summary.len(), 2);
        assert!((summary[0].slo_violation_rate - 0.02).abs() < 1e-12);
        assert!((summary[1].cost_per_1k - 22.0).abs() < 1e-12);
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 1);
        assert_eq!(ratios[0].platform, "kserve");
        assert!((ratios[0].cost_ratio.unwrap() - 11.0).abs() < 1e-9);
        assert!((ratios[0].violation_ratio.unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ablation_platforms_get_ratio_rows_too() {
        // The hybrid-vs-single-axis table is the same ratio machinery: any
        // non-has-gpu platform in the grid gets a baseline÷HAS row.
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![
                mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
                mk_cell("has-vertical-only", Preset::Standard, 1, 0.08, 1.5),
                mk_cell("has-horizontal-only", Preset::Standard, 1, 0.04, 2.0),
            ],
        };
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 2);
        assert_eq!(ratios[0].platform, "has-vertical-only");
        assert!((ratios[0].violation_ratio.unwrap() - 8.0).abs() < 1e-9);
        assert_eq!(ratios[1].platform, "has-horizontal-only");
        assert!((ratios[1].cost_ratio.unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_denominator_ratio_is_undefined_not_huge() {
        let mk = |platform: &str, viol: f64| CellResult {
            platform: platform.to_string(),
            fleet: DEFAULT_FLEET.to_string(),
            fault: NO_FAULTS.to_string(),
            preset: Preset::Diurnal,
            seed: 1,
            served: 100,
            dropped: 0,
            failed: None,
            availability: None,
            mttr: None,
            slo_violation_rate: viol,
            p99_latency: 0.05,
            ttft_p50: None,
            ttft_p99: None,
            gpu_seconds: 10.0,
            total_cost: 1.0,
            cost_per_1k: 10.0,
            vertical_ups: 0,
            vertical_downs: 0,
            horizontal_ups: 0,
            horizontal_downs: 0,
            functions: Vec::new(),
            classes: Vec::new(),
            workflows: Vec::new(),
        };
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![mk("has-gpu", 0.0), mk("kserve", 0.02)],
        };
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios[0].violation_ratio, None);
        assert_eq!(ratios[0].cost_ratio, Some(1.0));
        // And the JSON export writes null, which still parses back.
        let j = report.to_json();
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
    }

    #[test]
    fn synthetic_report_json_roundtrips() {
        let report = MatrixReport {
            seconds: 30,
            gpus: 2,
            rps: 10.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![CellResult {
                platform: "fast-gshare".to_string(),
                fleet: DEFAULT_FLEET.to_string(),
                fault: NO_FAULTS.to_string(),
                preset: Preset::SpikyBurst,
                seed: 42,
                served: 10,
                dropped: 1,
                failed: None,
                availability: None,
                mttr: None,
                slo_violation_rate: 0.25,
                p99_latency: 0.125,
                ttft_p50: None,
                ttft_p99: None,
                gpu_seconds: 1.5,
                total_cost: 0.0125,
                cost_per_1k: 1.25,
                vertical_ups: 0,
                vertical_downs: 0,
                horizontal_ups: 2,
                horizontal_downs: 1,
                functions: vec![FunctionCellMetrics {
                    name: "resnet50".into(),
                    slo: 0.05,
                    served: 10,
                    dropped: 1,
                    p50: 0.02,
                    p99: 0.125,
                    violation_rate: 0.25,
                    cost: 0.0125,
                    gpu_seconds: 1.5,
                    cost_per_1k: 1.25,
                }],
                classes: Vec::new(),
                workflows: Vec::new(),
            }],
        };
        let j = report.to_json();
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back, report);
        assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
        // Table renders every summary row.
        assert!(report.table().contains("spiky-burst"));
        assert!(report.table().contains("fast-gshare"));
    }

    #[test]
    fn custom_platform_cells_roundtrip_through_json() {
        // Open registry ⇒ open export: a caller-registered platform's cells
        // parse back without any enum to amend.
        let report = MatrixReport {
            seconds: 10,
            gpus: 1,
            rps: 1.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![mk_cell("esg-pipeline", Preset::Standard, 1, 0.5, 9.0)],
        };
        let j = report.to_json();
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back, report);
        // Empty platform names are still rejected.
        let bad = Json::obj(vec![("platform", Json::Str(String::new()))]);
        assert!(CellResult::from_json(&bad).is_err());
    }

    #[test]
    fn bad_schema_rejected() {
        let j = Json::obj(vec![("schema", Json::Str("something/else".into()))]);
        assert!(MatrixReport::from_json(&j).is_err());
    }

    #[test]
    fn storm_cells_carry_ttft_keys_and_standard_cells_do_not() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu"]),
            presets: vec![Preset::Standard, Preset::ColdStartStorm],
            seeds: vec![4],
            seconds: 240,
            gpus: 6,
            rps: 40.0,
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        let (std_report, std_cell) = m.run_cell(&cells[0]);
        let (storm_report, storm_cell) = m.run_cell(&cells[1]);
        // Standard: pre-lifecycle schema to the byte — no TTFT anywhere.
        assert!(!std_report.lifecycle);
        assert_eq!(std_cell.ttft_p50, None);
        assert!(std_cell.to_json().opt("ttft_p50").is_none());
        assert!(std_cell.to_json().opt("ttft_p99").is_none());
        // Storm: lifecycle on, cold fleet, real swap latencies ⇒ TTFT
        // populated and exported.
        assert!(storm_report.lifecycle);
        let (p50, p99) = (storm_cell.ttft_p50.unwrap(), storm_cell.ttft_p99.unwrap());
        assert!(p50 >= 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
        assert!(
            storm_cell.to_json().opt("ttft_p99").is_some(),
            "storm cells export TTFT keys"
        );
        // Cold fleet + finite load bandwidth: anyone actually served had
        // to wait out at least one cold load first.
        if storm_cell.served > 0 {
            assert!(p99 > 0.0, "cold-start storm must observe non-zero TTFT");
        }
        // And lifecycle cells round-trip losslessly through JSON.
        let back = CellResult::from_json(&storm_cell.to_json()).unwrap();
        assert_eq!(back, storm_cell);
        assert_eq!(
            back.to_json().to_string_pretty(),
            storm_cell.to_json().to_string_pretty()
        );
    }

    #[test]
    fn fault_axis_enumerates_between_preset_and_fleet() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu", "kserve"]),
            presets: vec![Preset::Standard],
            seeds: vec![1, 2],
            faults: strs(&["no-faults", "chaos-gpu-failures"]),
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        assert_eq!(cells.len(), 8);
        // fault-major inside each preset: all no-fault cells first.
        assert!(cells[..4].iter().all(|c| c.fault == NO_FAULTS));
        assert!(cells[4..].iter().all(|c| c.fault == "chaos-gpu-failures"));
        assert_eq!(cells[4].platform, "has-gpu");
        assert_eq!(cells[6].platform, "kserve");
    }

    #[test]
    fn fault_preset_parsing() {
        assert_eq!(
            parse_faults(&strs(&["no-faults", "chaos-gpu-failures"])).unwrap(),
            strs(&["no-faults", "chaos-gpu-failures"])
        );
        // Case-insensitive, deduplicated.
        assert_eq!(
            parse_faults(&strs(&["Chaos-Flaky-Reconfig", "chaos-flaky-reconfig"])).unwrap(),
            strs(&["chaos-flaky-reconfig"])
        );
        let err = parse_faults(&strs(&["chaos-meteor"])).unwrap_err().to_string();
        assert!(err.contains("no-faults") && err.contains("chaos-gpu-failures"), "{err}");
        assert!(parse_faults(&[]).is_err());
    }

    #[test]
    fn no_fault_cells_export_no_fault_keys_and_chaos_cells_do() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu"]),
            presets: vec![Preset::Standard],
            seeds: vec![3],
            seconds: 60,
            gpus: 6,
            rps: 40.0,
            faults: strs(&["no-faults", "chaos-gpu-failures"]),
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        let (calm_report, calm) = m.run_cell(&cells[0]);
        let (chaos_report, chaos) = m.run_cell(&cells[1]);
        // No-faults: pre-fault schema to the byte — no fault keys anywhere.
        assert!(!calm_report.faults_active);
        assert_eq!((calm.failed, calm.availability, calm.mttr), (None, None, None));
        for key in ["fault", "failed", "availability", "mttr"] {
            assert!(calm.to_json().opt(key).is_none(), "unexpected {key} key");
        }
        // Chaos: fault keys present, availability a real fraction.
        assert!(chaos_report.faults_active);
        assert_eq!(chaos.fault, "chaos-gpu-failures");
        assert_eq!(
            chaos.to_json().opt("fault").and_then(|v| v.as_str().ok()),
            Some("chaos-gpu-failures")
        );
        let avail = chaos.availability.expect("chaos cells report availability");
        assert!((0.0..=1.0).contains(&avail), "availability {avail}");
        assert!(chaos.to_json().opt("availability").is_some());
        assert!(chaos.to_json().opt("failed").is_some());
        // Chaos cells round-trip through JSON losslessly.
        let back = CellResult::from_json(&chaos.to_json()).unwrap();
        assert_eq!(back, chaos);
        assert_eq!(back.to_json().to_string_pretty(), chaos.to_json().to_string_pretty());
    }

    #[test]
    fn fault_rows_flow_into_summary_table_and_ratios() {
        let mut chaos_has = mk_cell("has-gpu", Preset::Standard, 1, 0.02, 1.2);
        chaos_has.fault = "chaos-gpu-failures".into();
        chaos_has.failed = Some(12);
        chaos_has.availability = Some(0.95);
        chaos_has.mttr = Some(2.0);
        let mut chaos_ks = mk_cell("kserve", Preset::Standard, 1, 0.08, 14.0);
        chaos_ks.fault = "chaos-gpu-failures".into();
        chaos_ks.failed = Some(30);
        chaos_ks.availability = Some(0.95);
        chaos_ks.mttr = Some(16.0);
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: strs(&["no-faults", "chaos-gpu-failures"]),
            cells: vec![
                mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
                mk_cell("kserve", Preset::Standard, 1, 0.05, 10.0),
                chaos_has,
                chaos_ks,
            ],
        };
        // Groups split on the fault axis: four rows, chaos rows carrying
        // availability/MTTR and calm rows not.
        let summary = report.summary();
        assert_eq!(summary.len(), 4);
        assert_eq!(summary[0].fault, NO_FAULTS);
        assert_eq!(summary[0].availability, None);
        assert_eq!(summary[2].fault, "chaos-gpu-failures");
        assert_eq!(summary[2].availability, Some(0.95));
        assert_eq!(summary[3].mttr, Some(16.0));
        // Ratios pair within a fault preset; chaos rows gain mttr_ratio.
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 2);
        assert_eq!(ratios[0].fault, NO_FAULTS);
        assert_eq!(ratios[0].mttr_ratio, None);
        assert!((ratios[0].cost_ratio.unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(ratios[1].fault, "chaos-gpu-failures");
        assert!((ratios[1].mttr_ratio.unwrap() - 8.0).abs() < 1e-9, "{ratios:?}");
        // JSON: the key only exists where the ratio does.
        let j = report.to_json();
        let jr = j.get("ratios_vs_has_gpu").unwrap().as_arr().unwrap();
        assert!(jr[0].opt("mttr_ratio").is_none());
        assert!(jr[1].opt("mttr_ratio").is_some());
        // Config echoes the fault axis for chaos grids.
        assert!(j.get("config").unwrap().opt("faults").is_some());
        // Table grows fault columns exactly when some cell has them.
        let t = report.table();
        assert!(t.contains("fault") && t.contains("avail") && t.contains("mttr"));
        let plain = MatrixReport {
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0)],
            ..report.clone()
        };
        assert!(!plain.table().contains("avail"));
        // And the whole fault-bearing report round-trips.
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back, report);
        assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
    }

    #[test]
    fn ttft_flows_into_summary_table_and_ratios() {
        let mut has = mk_cell("has-gpu", Preset::ColdStartStorm, 1, 0.01, 1.0);
        has.ttft_p50 = Some(0.01);
        has.ttft_p99 = Some(0.05);
        let mut torpor = mk_cell("torpor-like", Preset::ColdStartStorm, 1, 0.02, 0.8);
        torpor.ttft_p50 = Some(0.2);
        torpor.ttft_p99 = Some(1.0);
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![
                mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
                mk_cell("torpor-like", Preset::Standard, 1, 0.02, 0.8),
                has,
                torpor,
            ],
        };
        let summary = report.summary();
        assert_eq!(summary.len(), 4);
        // Standard rows stay TTFT-free; storm rows carry it.
        assert_eq!(summary[0].ttft_p99, None);
        assert_eq!(summary[2].ttft_p99, Some(0.05));
        assert_eq!(summary[3].ttft_p99, Some(1.0));
        // Ratio rows: standard omits ttft_ratio, storm carries 20x.
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 2);
        assert_eq!(ratios[0].preset, Preset::Standard);
        assert_eq!(ratios[0].ttft_ratio, None);
        assert_eq!(ratios[1].preset, Preset::ColdStartStorm);
        assert!((ratios[1].ttft_ratio.unwrap() - 20.0).abs() < 1e-9);
        // JSON: the key only exists where the ratio does.
        let j = report.to_json();
        let jr = j.get("ratios_vs_has_gpu").unwrap().as_arr().unwrap();
        assert!(jr[0].opt("ttft_ratio").is_none());
        assert!(jr[1].opt("ttft_ratio").is_some());
        // Table grows TTFT columns exactly when some row has them.
        assert!(report.table().contains("ttft-p99"));
        let plain = MatrixReport {
            cells: vec![mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0)],
            ..report.clone()
        };
        assert!(!plain.table().contains("ttft"));
        // And the whole lifecycle-bearing report round-trips.
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back, report);
        assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
    }

    #[test]
    fn pipeline_cells_carry_workflow_keys_and_stock_cells_do_not() {
        let m = ScenarioMatrix {
            platforms: strs(&["has-gpu"]),
            presets: vec![Preset::Standard, Preset::PipelineVision],
            seeds: vec![5],
            seconds: 120,
            gpus: 6,
            rps: 40.0,
            ..ScenarioMatrix::default()
        };
        let cells = m.cells();
        let (_, std_cell) = m.run_cell(&cells[0]);
        let (pipe_report, pipe_cell) = m.run_cell(&cells[1]);
        // Standard: pre-workflow schema to the byte — no workflow keys.
        assert!(std_cell.workflows.is_empty());
        assert!(std_cell.to_json().opt("workflows").is_none());
        // Pipeline: the cell's function set is the workflow's stage set,
        // and the workflow row carries real e2e numbers.
        assert_eq!(pipe_cell.workflows.len(), 1);
        let wf = &pipe_cell.workflows[0];
        assert_eq!(wf.name, "pipeline-vision");
        assert!(wf.served > 0, "pipeline served {}", wf.served);
        assert!(wf.e2e_p99 > 0.0 && wf.e2e_p99.is_finite());
        assert!(wf.e2e_p50 <= wf.e2e_p99);
        assert!((0.0..=1.0).contains(&wf.e2e_violation_rate));
        assert!(wf.cost > 0.0 && wf.cost_per_1k > 0.0);
        assert_eq!(pipe_cell.functions.len(), 2);
        assert!(pipe_cell
            .functions
            .iter()
            .all(|f| f.name.starts_with("pipeline-vision:")));
        // The chain cost is exactly the sum of its stage-function costs.
        let stage_cost: f64 = pipe_cell.functions.iter().map(|f| f.cost).sum();
        assert!((wf.cost - stage_cost).abs() < 1e-9);
        assert_eq!(pipe_report.workflow_slos.len(), 1);
        assert!(pipe_cell.to_json().opt("workflows").is_some());
        // Pipeline cells round-trip losslessly through JSON.
        let back = CellResult::from_json(&pipe_cell.to_json()).unwrap();
        assert_eq!(back, pipe_cell);
        assert_eq!(
            back.to_json().to_string_pretty(),
            pipe_cell.to_json().to_string_pretty()
        );
    }

    fn mk_wf(e2e_p99: f64, cost_per_1k: f64) -> WorkflowCellMetrics {
        WorkflowCellMetrics {
            name: "pipeline-mixed".into(),
            e2e_slo: 0.5,
            served: 100,
            dropped: 0,
            e2e_p50: e2e_p99 / 2.0,
            e2e_p99,
            e2e_violation_rate: 0.0,
            cost: cost_per_1k / 10.0,
            cost_per_1k,
        }
    }

    #[test]
    fn workflow_metrics_flow_into_summary_table_and_ratios() {
        let mut has = mk_cell("has-gpu", Preset::PipelineMixed, 1, 0.01, 1.0);
        has.workflows = vec![mk_wf(0.1, 2.0)];
        let mut ks = mk_cell("kserve", Preset::PipelineMixed, 1, 0.02, 0.8);
        ks.workflows = vec![mk_wf(0.4, 6.0)];
        let report = MatrixReport {
            seconds: 60,
            gpus: 4,
            rps: 50.0,
            fleets: vec![DEFAULT_FLEET.to_string()],
            faults: vec![NO_FAULTS.to_string()],
            cells: vec![
                mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0),
                mk_cell("kserve", Preset::Standard, 1, 0.02, 0.8),
                has,
                ks,
            ],
        };
        let summary = report.summary();
        assert_eq!(summary.len(), 4);
        // Standard rows stay workflow-free; pipeline rows carry e2e columns.
        assert_eq!(summary[0].e2e_p99, None);
        assert_eq!(summary[2].e2e_p99, Some(0.1));
        assert_eq!(summary[2].e2e_cost_per_1k, Some(2.0));
        assert_eq!(summary[3].e2e_p99, Some(0.4));
        // Ratio rows: standard omits e2e_ratio, the pipeline pair is 4x.
        let ratios = report.ratios_vs_has_gpu();
        assert_eq!(ratios.len(), 2);
        assert_eq!(ratios[0].preset, Preset::Standard);
        assert_eq!(ratios[0].e2e_ratio, None);
        assert_eq!(ratios[1].preset, Preset::PipelineMixed);
        assert!((ratios[1].e2e_ratio.unwrap() - 4.0).abs() < 1e-9);
        // JSON: the key only exists where the ratio does.
        let j = report.to_json();
        let jr = j.get("ratios_vs_has_gpu").unwrap().as_arr().unwrap();
        assert!(jr[0].opt("e2e_ratio").is_none());
        assert!(jr[1].opt("e2e_ratio").is_some());
        // Table grows the e2e columns exactly when some row has them.
        assert!(report.table().contains("e2e-p99"));
        let plain = MatrixReport {
            cells: vec![mk_cell("has-gpu", Preset::Standard, 1, 0.01, 1.0)],
            ..report.clone()
        };
        assert!(!plain.table().contains("e2e"));
        // And the whole workflow-bearing report round-trips.
        let back = MatrixReport::from_json(&j).unwrap();
        assert_eq!(back, report);
        assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
    }
}
