//! Fleet presets: named GPU-class compositions the scenario matrix can
//! resolve by name, exactly like platforms.
//!
//! A **fleet** declares *what mix of device classes* a cell's cluster is
//! built from; the matrix's `--gpus` knob still sets the device count, and
//! [`FleetSpec::classes_for`] distributes it across the declared classes
//! deterministically (largest-remainder over the declared weights, ties by
//! declaration order, devices emitted grouped in declaration order — GPU
//! index is a placement tie-break, so the ordering is part of the fleet's
//! identity).
//!
//! **Name stability:** fleet names are export keys (`BENCH_sim.json` cells
//! carry their fleet; summary/ratio rows group by it). The default
//! [`DEFAULT_FLEET`] (`uniform-v100`) is special: it reproduces the
//! pre-fleet homogeneous cluster byte-for-byte and is *omitted* from the
//! export, so stock grids never change a byte (pinned by
//! `rust/tests/expt_golden.rs`).

use crate::util::bench::ascii_table;
use crate::vgpu::GpuClass;

/// The fleet every pre-fleet grid implicitly ran on. Cells on this fleet
/// export no `fleet` key — byte-stability of the stock schema.
pub const DEFAULT_FLEET: &str = "uniform-v100";

/// A named GPU-class composition.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Stable registry key (export schema; see module docs).
    pub name: String,
    /// One-line description for `--help` and the `fleets` subcommand.
    pub about: String,
    /// (class, weight) in declaration order; `classes_for` splits the
    /// device count proportionally to the weights.
    pub groups: Vec<(GpuClass, u32)>,
}

impl FleetSpec {
    /// A single-class fleet.
    pub fn uniform(name: impl Into<String>, about: impl Into<String>, class: GpuClass) -> Self {
        FleetSpec {
            name: name.into(),
            about: about.into(),
            groups: vec![(class, 1)],
        }
    }

    pub fn is_uniform(&self) -> bool {
        self.groups.len() == 1
    }

    /// Does this fleet reproduce the pre-fleet homogeneous cluster?
    pub fn is_reference_uniform(&self) -> bool {
        self.is_uniform() && self.groups[0].0.is_reference()
    }

    /// Deterministic composition for `n_gpus` devices: floor the
    /// proportional share per class, hand the remainder out by largest
    /// fractional part (ties → declaration order), emit devices grouped in
    /// declaration order. Always returns exactly `n_gpus` entries.
    pub fn classes_for(&self, n_gpus: usize) -> Vec<GpuClass> {
        let total_w: u64 = self.groups.iter().map(|(_, w)| *w as u64).sum();
        debug_assert!(total_w > 0, "fleet '{}' has zero total weight", self.name);
        let n = n_gpus as u64;
        let mut counts: Vec<u64> = Vec::with_capacity(self.groups.len());
        let mut fracs: Vec<(u64, usize)> = Vec::with_capacity(self.groups.len()); // (remainder numerator, idx)
        let mut assigned = 0u64;
        for (i, (_, w)) in self.groups.iter().enumerate() {
            let num = n * *w as u64;
            counts.push(num / total_w);
            fracs.push((num % total_w, i));
            assigned += num / total_w;
        }
        // Largest remainder first; equal remainders in declaration order.
        fracs.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut leftover = n - assigned;
        for &(_, i) in &fracs {
            if leftover == 0 {
                break;
            }
            counts[i] += 1;
            leftover -= 1;
        }
        let mut out = Vec::with_capacity(n_gpus);
        for (i, (class, _)) in self.groups.iter().enumerate() {
            for _ in 0..counts[i] {
                out.push(class.clone());
            }
        }
        debug_assert_eq!(out.len(), n_gpus);
        out
    }

    /// Device count per class name for `n_gpus` (per-class occupancy
    /// columns), in declaration order, zero-count classes included.
    pub fn class_counts(&self, n_gpus: usize) -> Vec<(String, usize)> {
        let classes = self.classes_for(n_gpus);
        self.groups
            .iter()
            .map(|(c, _)| {
                let n = classes.iter().filter(|x| x.name == c.name).count();
                (c.name.clone(), n)
            })
            .collect()
    }
}

/// Ordered collection of [`FleetSpec`]s; registration order is listing
/// order. Mirrors [`super::PlatformRegistry`]'s contract: case-insensitive
/// lookup, duplicate and CLI-unreachable names rejected, unknown names
/// error with the full menu.
#[derive(Clone, Debug)]
pub struct FleetRegistry {
    specs: Vec<FleetSpec>,
}

impl Default for FleetRegistry {
    /// `uniform-v100` (the byte-stable default) plus the mixed
    /// A100/V100/T4 fleet (1:2:1 by weight) the heterogeneity experiments
    /// run on.
    fn default() -> Self {
        let mut reg = FleetRegistry::empty();
        reg.register(FleetSpec::uniform(
            DEFAULT_FLEET,
            "homogeneous V100 rack (the paper's testbed; byte-stable default)",
            GpuClass::v100(),
        ))
        .unwrap();
        reg.register(FleetSpec {
            name: "mixed-a100-v100-t4".into(),
            about: "heterogeneous rack: A100 : V100 : T4 at 1 : 2 : 1".into(),
            groups: vec![
                (GpuClass::a100(), 1),
                (GpuClass::v100(), 2),
                (GpuClass::t4(), 1),
            ],
        })
        .unwrap();
        reg
    }
}

impl FleetRegistry {
    pub fn empty() -> Self {
        FleetRegistry { specs: Vec::new() }
    }

    /// Append a spec; names are case-insensitive keys with the same
    /// reachability rules as platform names.
    pub fn register(&mut self, spec: FleetSpec) -> anyhow::Result<()> {
        anyhow::ensure!(!spec.name.is_empty(), "fleet name must be non-empty");
        anyhow::ensure!(
            spec.name.trim() == spec.name,
            "fleet name '{}' must not have surrounding whitespace",
            spec.name
        );
        anyhow::ensure!(
            !spec.name.contains(','),
            "fleet name '{}' must not contain ',' (the CLI list separator)",
            spec.name
        );
        anyhow::ensure!(
            !spec.groups.is_empty() && spec.groups.iter().any(|(_, w)| *w > 0),
            "fleet '{}' needs at least one positively-weighted class",
            spec.name
        );
        anyhow::ensure!(
            self.get(&spec.name).is_none(),
            "fleet '{}' is already registered",
            spec.name
        );
        self.specs.push(spec);
        Ok(())
    }

    /// Case-insensitive lookup.
    pub fn get(&self, name: &str) -> Option<&FleetSpec> {
        self.specs
            .iter()
            .find(|s| s.name.eq_ignore_ascii_case(name.trim()))
    }

    pub fn specs(&self) -> &[FleetSpec] {
        &self.specs
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    /// Expand a `--fleets` token list into canonical registry names,
    /// deduplicated in first-appearance order.
    pub fn resolve(&self, tokens: &[String]) -> anyhow::Result<Vec<String>> {
        anyhow::ensure!(!tokens.is_empty(), "need at least one fleet");
        let mut out: Vec<String> = Vec::new();
        for tok in tokens {
            let t = tok.trim();
            let Some(spec) = self.get(t) else {
                anyhow::bail!(
                    "unknown fleet '{t}' (expected one of: {})",
                    self.names().join(", ")
                );
            };
            if !out.iter().any(|n| n == &spec.name) {
                out.push(spec.name.clone());
            }
        }
        Ok(out)
    }

    /// One-line inventory for `--help` text.
    pub fn cli_help(&self) -> String {
        format!("comma list of fleet names; names: {}", self.names().join(", "))
    }

    /// The `has-gpu fleets` inventory table.
    pub fn table(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .specs
            .iter()
            .map(|s| {
                let mix = s
                    .groups
                    .iter()
                    .map(|(c, w)| format!("{}:{w}", c.name))
                    .collect::<Vec<_>>()
                    .join(" ");
                vec![s.name.clone(), mix, s.about.clone()]
            })
            .collect();
        ascii_table(&["fleet", "class:weight", "description"], &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_registry_has_byte_stable_default_first() {
        let reg = FleetRegistry::default();
        assert_eq!(reg.names(), vec![DEFAULT_FLEET, "mixed-a100-v100-t4"]);
        assert!(reg.get(DEFAULT_FLEET).unwrap().is_reference_uniform());
        assert!(!reg.get("mixed-a100-v100-t4").unwrap().is_uniform());
        assert!(reg.get("Uniform-V100").is_some(), "lookup is case-insensitive");
    }

    #[test]
    fn classes_for_distributes_exactly_n_deterministically() {
        let reg = FleetRegistry::default();
        let mixed = reg.get("mixed-a100-v100-t4").unwrap();
        for n in [1usize, 2, 3, 4, 6, 10, 17, 100] {
            let classes = mixed.classes_for(n);
            assert_eq!(classes.len(), n, "n={n}");
            assert_eq!(classes, mixed.classes_for(n), "must be deterministic");
        }
        // 10 devices at 1:2:1 → remainders tie between a100 and t4; the
        // declaration order hands the spare to the a100.
        let counts = mixed.class_counts(10);
        assert_eq!(
            counts,
            vec![
                ("a100".to_string(), 3),
                ("v100".to_string(), 5),
                ("t4".to_string(), 2)
            ]
        );
        // Devices come out grouped in declaration order.
        let classes = mixed.classes_for(10);
        assert_eq!(classes[0].name, "a100");
        assert_eq!(classes[3].name, "v100");
        assert_eq!(classes[8].name, "t4");
        // The uniform default is all reference class.
        let uni = reg.get(DEFAULT_FLEET).unwrap().classes_for(4);
        assert!(uni.iter().all(|c| c.is_reference()));
    }

    #[test]
    fn resolve_dedupes_and_errors_with_menu() {
        let reg = FleetRegistry::default();
        assert_eq!(
            reg.resolve(&["MIXED-A100-V100-T4".to_string(), DEFAULT_FLEET.to_string()])
                .unwrap(),
            vec!["mixed-a100-v100-t4".to_string(), DEFAULT_FLEET.to_string()]
        );
        assert_eq!(
            reg.resolve(&[DEFAULT_FLEET.to_string(), DEFAULT_FLEET.to_string()])
                .unwrap()
                .len(),
            1
        );
        let err = reg.resolve(&["gpu-zoo".to_string()]).unwrap_err().to_string();
        assert!(err.contains(DEFAULT_FLEET) && err.contains("mixed-a100-v100-t4"), "{err}");
        assert!(reg.resolve(&[]).is_err());
    }

    #[test]
    fn registration_rejects_unreachable_and_duplicate_names() {
        let mut reg = FleetRegistry::default();
        for bad in ["", " padded", "a,b", DEFAULT_FLEET, "UNIFORM-V100"] {
            let spec = FleetSpec::uniform(bad, "bad", GpuClass::v100());
            assert!(reg.register(spec).is_err(), "'{bad}' must be rejected");
        }
        let zero = FleetSpec {
            name: "zero-weight".into(),
            about: "no classes".into(),
            groups: vec![(GpuClass::v100(), 0)],
        };
        assert!(reg.register(zero).is_err());
        // A fresh custom fleet registers, resolves, and lists.
        reg.register(FleetSpec::uniform("uniform-t4", "budget rack", GpuClass::t4()))
            .unwrap();
        assert_eq!(reg.resolve(&["uniform-t4".into()]).unwrap(), vec!["uniform-t4"]);
        assert!(reg.table().contains("uniform-t4"));
        assert!(reg.cli_help().contains("uniform-t4"));
    }
}
