//! FeaturePlan / batched-forward parity: the cached extraction split and the
//! row-batched lattice forward must be **bit-identical** to fresh per-query
//! extraction and scalar forwards for every zoo model across the full probe
//! lattice. This is the contract that lets the autoscaler and the sim share
//! plan-cached predictors without perturbing the byte-identical
//! `BENCH_sim.json` export.

use has_gpu::model::zoo::{zoo_graph, ALL_ZOO};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::{extract, FeatureMode, FeaturePlan};
use has_gpu::rapp::{CachedPredictor, LatencyPredictor, PredictQuery, RappPredictor, RappWeights};

/// The seed's feature extraction, frozen **verbatim** (modulo imports) from
/// the pre-FeaturePlan `rapp::features::extract`. This is the independent
/// reference that pins the historical f32 operation order: the production
/// `extract` now delegates to `FeaturePlan`, so comparing plan output against
/// production `extract` alone would be tautological. Do not "clean this up" —
/// its sole job is to stay byte-for-byte faithful to the seed arithmetic.
mod seed_reference {
    use has_gpu::model::{OpGraph, OpKind, NUM_OP_KINDS};
    use has_gpu::perf::PerfModel;
    use has_gpu::rapp::features::{FeatureMode, F_OP_STATIC};

    pub struct SeedFeatures {
        pub op_feats: Vec<Vec<f32>>,
        pub graph_feats: Vec<f32>,
        pub edges: Vec<(usize, usize)>,
    }

    pub fn extract(
        g: &OpGraph,
        batch: u32,
        sm: f64,
        quota: f64,
        perf: &PerfModel,
        mode: FeatureMode,
    ) -> SeedFeatures {
        let b = batch as f64;
        let mut op_feats = Vec::with_capacity(g.nodes.len());
        for op in &g.nodes {
            let mut f = Vec::with_capacity(mode.f_op());
            // One-hot kind.
            for k in 0..NUM_OP_KINDS {
                f.push(if op.kind.index() == k { 1.0 } else { 0.0 });
            }
            // Static shape descriptors (normalised to O(1) ranges).
            f.push(ln1p(op.flops * b / 1e6) as f32);
            f.push(ln1p((op.bytes * b + 4.0 * op.params) / 1e6) as f32);
            f.push(ln1p(op.params / 1e6) as f32);
            f.push(op.kernel as f32 / 7.0);
            f.push(op.stride as f32 / 4.0);
            f.push(op.cin as f32 / 1024.0);
            f.push(op.cout as f32 / 1024.0);
            f.push(op.spatial as f32 / 256.0);
            f.push((b.log2() / 5.0) as f32);
            // Runtime priors: profiled op time at the 6 SM points, full quota.
            if mode == FeatureMode::Full {
                for &sm_p in PerfModel::PROFILE_SMS.iter() {
                    f.push(ln1p(perf.op_time(op, batch, sm_p) * 1e3) as f32);
                }
            }
            op_feats.push(f);
        }

        let mut gf = Vec::with_capacity(mode.f_g());
        gf.push(ln1p(g.total_flops(batch) / 1e9) as f32);
        gf.push(ln1p(g.total_bytes(batch) / 1e9) as f32);
        gf.push(ln1p(g.total_params() / 1e6) as f32);
        gf.push(g.nodes.len() as f32 / 64.0);
        gf.push(g.count_kind(OpKind::Conv2d) as f32 / 32.0);
        gf.push((g.count_kind(OpKind::Dense) + g.count_kind(OpKind::MatMul)) as f32 / 32.0);
        gf.push(g.depth() as f32 / 64.0);
        gf.push((b.log2() / 5.0) as f32);
        gf.push(sm as f32);
        gf.push(quota as f32);
        // Runtime priors: graph latency at the 5 quota points (full SM), then
        // raw graph time at the 6 SM points (full quota).
        if mode == FeatureMode::Full {
            for &q_p in PerfModel::PROFILE_QUOTAS.iter() {
                gf.push(ln1p(perf.latency(g, batch, 1.0, q_p) * 1e3) as f32);
            }
            for &sm_p in PerfModel::PROFILE_SMS.iter() {
                gf.push(ln1p(perf.raw_graph_time(g, batch, sm_p) * 1e3) as f32);
            }
            let a = anchor(g, &op_feats, sm, quota, perf.dev.window);
            gf.push(a);
        }

        SeedFeatures {
            op_feats,
            graph_feats: gf,
            edges: g.edges.clone(),
        }
    }

    #[inline]
    fn ln1p(x: f64) -> f64 {
        (1.0 + x).ln()
    }

    /// Seed's piecewise-linear interpolation, frozen verbatim.
    fn interp(xs: &[f64], ys: &[f32], x: f64) -> f64 {
        if x <= xs[0] {
            return ys[0] as f64;
        }
        if x >= xs[xs.len() - 1] {
            return ys[ys.len() - 1] as f64;
        }
        for i in 0..xs.len() - 1 {
            if x <= xs[i + 1] {
                let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
                return ys[i] as f64 * (1.0 - t) + ys[i + 1] as f64 * t;
            }
        }
        ys[ys.len() - 1] as f64
    }

    /// Seed's anchor (probe-interpolated token-window replay), frozen
    /// verbatim — including the `Vec`-built ln-SM axis.
    fn anchor(g: &OpGraph, op_feats: &[Vec<f32>], sm: f64, quota: f64, window: f64) -> f32 {
        let ln_sms: Vec<f64> = PerfModel::PROFILE_SMS.iter().map(|s| s.ln()).collect();
        let ln_sm = sm.clamp(1e-3, 1.0).ln();
        let mut now = 0.0f64;
        let mut budget = quota * window;
        let mut boundary = window;
        for (i, node) in g.nodes.iter().enumerate() {
            let ln_t = interp(&ln_sms, &op_feats[i][F_OP_STATIC..F_OP_STATIC + 6], ln_sm);
            let t_est = ln_t.exp_m1() / 1e3; // invert ln1p(ms)
            let k = node.kernels.max(1);
            let d = t_est / k as f64;
            for _ in 0..k {
                if boundary <= now {
                    let skipped = ((now - boundary) / window).floor() + 1.0;
                    boundary += skipped * window;
                    budget = quota * window;
                }
                if budget <= 0.0 {
                    now = boundary;
                    boundary += window;
                    budget = quota * window;
                }
                now += d;
                budget -= d;
            }
        }
        // ln(ms), matching the regression target's transform exactly.
        (now * 1e3).max(1e-9).ln() as f32
    }
}

/// The (sm, quota) probe lattice the scaling sweeps walk: every per-mille
/// decile on both axes.
fn lattice() -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    for smi in [1u32, 2, 4, 7, 10] {
        for qi in 1..=10u32 {
            out.push((smi as f64 / 10.0, qi as f64 / 10.0));
        }
    }
    out
}

#[test]
fn plan_cached_extraction_bit_identical_to_seed_extract() {
    // Three-way pin across the full probe lattice: the frozen SEED extraction
    // (the independent reference — production `extract` now delegates to
    // FeaturePlan, so comparing only those two would be tautological), the
    // production one-shot `extract`, and a single cached plan reused across
    // every query.
    let pm = PerfModel::default();
    for m in ALL_ZOO {
        let g = zoo_graph(m);
        for mode in [FeatureMode::Full, FeatureMode::StaticOnly] {
            for batch in [1u32, 8] {
                let plan = FeaturePlan::new(&g, batch, &pm, mode);
                let mut gf = Vec::new();
                for (sm, quota) in lattice() {
                    let seed = seed_reference::extract(&g, batch, sm, quota, &pm, mode);
                    let fresh = extract(&g, batch, sm, quota, &pm, mode);
                    plan.fill_graph_feats(sm, quota, &mut gf);
                    // The GpuClass catalog appended exactly one trailing
                    // graph column (the class throughput factor, 1.0 on the
                    // reference class); every seed-era column keeps its
                    // index and its bits.
                    assert_eq!(gf.len(), seed.graph_feats.len() + 1);
                    assert_eq!(fresh.graph_feats.len(), seed.graph_feats.len() + 1);
                    assert_eq!(gf.last().unwrap().to_bits(), 1.0f32.to_bits());
                    assert_eq!(fresh.graph_feats.last().unwrap().to_bits(), 1.0f32.to_bits());
                    for (c, ((a, b), s)) in gf
                        .iter()
                        .zip(&fresh.graph_feats)
                        .zip(&seed.graph_feats)
                        .enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            s.to_bits(),
                            "{m:?} {mode:?} b{batch} sm={sm} q={quota} graph col {c}: plan vs seed"
                        );
                        assert_eq!(
                            b.to_bits(),
                            s.to_bits(),
                            "{m:?} {mode:?} b{batch} sm={sm} q={quota} graph col {c}: extract vs seed"
                        );
                    }
                    for (i, seed_row) in seed.op_feats.iter().enumerate() {
                        let plan_row = plan.op_row(i);
                        assert_eq!(plan_row.len(), seed_row.len());
                        assert_eq!(fresh.op_feats[i].len(), seed_row.len());
                        for (c, (a, s)) in plan_row.iter().zip(seed_row).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                s.to_bits(),
                                "{m:?} {mode:?} b{batch} node {i} op col {c}: plan vs seed"
                            );
                            assert_eq!(
                                fresh.op_feats[i][c].to_bits(),
                                s.to_bits(),
                                "{m:?} {mode:?} b{batch} node {i} op col {c}: extract vs seed"
                            );
                        }
                    }
                    assert_eq!(seed.edges, plan.edges);
                    assert_eq!(fresh.edges, plan.edges);
                }
            }
        }
    }
}

#[test]
fn batched_forward_bit_identical_to_scalar_across_lattice() {
    let pm = PerfModel::default();
    let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    for mode in [FeatureMode::Full, FeatureMode::StaticOnly] {
        // Shared predictor (warm plans) and a twin that stays cold per query:
        // plan reuse must not change a single bit.
        let warm = RappPredictor::new(RappWeights::random(mode, 32, 17), pm.clone());
        let cold = RappPredictor::new(RappWeights::random(mode, 32, 17), pm.clone());
        for m in ALL_ZOO {
            let g = zoo_graph(m);
            for &sm in &[0.2, 0.5, 1.0] {
                let mut batched = Vec::new();
                warm.forward_batch(&g, 8, sm, &quotas, &mut batched);
                assert_eq!(batched.len(), quotas.len());
                for (&q, &b) in quotas.iter().zip(&batched) {
                    let scalar = warm.forward(&g, 8, sm, q);
                    assert_eq!(
                        scalar.to_bits(),
                        b.to_bits(),
                        "{m:?} {mode:?} sm={sm} q={q}: batched vs scalar"
                    );
                    cold.reset_plan_cache();
                    let fresh = cold.forward(&g, 8, sm, q);
                    assert_eq!(
                        scalar.to_bits(),
                        fresh.to_bits(),
                        "{m:?} {mode:?} sm={sm} q={q}: warm plan vs cold plan"
                    );
                }
            }
        }
    }
}

#[test]
fn cached_predictor_lattice_sweep_matches_scalar_latencies() {
    let pm = PerfModel::default();
    let rapp = RappPredictor::new(
        RappWeights::random(FeatureMode::Full, 32, 23),
        pm.clone(),
    );
    let reference = RappPredictor::new(
        RappWeights::random(FeatureMode::Full, 32, 23),
        pm.clone(),
    );
    let cached = CachedPredictor::new(&rapp);
    let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
    let mut out = Vec::new();
    for m in ALL_ZOO {
        let g = zoo_graph(m);
        cached.latency_batch(PredictQuery::new(&g, 8, 0.5, 1.0), &quotas, &mut out);
        for (&q, &v) in quotas.iter().zip(&out) {
            assert_eq!(
                v,
                reference.latency(PredictQuery::new(&g, 8, 0.5, q)),
                "{m:?} q={q}: cached sweep vs fresh scalar latency"
            );
            // Re-query scalar through the same cache: identical.
            assert_eq!(v, cached.latency(PredictQuery::new(&g, 8, 0.5, q)));
        }
    }
}
