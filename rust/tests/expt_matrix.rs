//! Scenario-matrix integration tests: the `expt` runner must produce
//! identical grids regardless of `--jobs`, and its `BENCH_sim.json` export
//! must round-trip losslessly through `util::json`.

use has_gpu::expt::{MatrixReport, ScenarioMatrix};
use has_gpu::util::json;
use has_gpu::workload::Preset;

/// 2 platforms × 1 preset × 2 seeds on a short trace — small enough for
/// `cargo test -q`, big enough to exercise sharding and aggregation.
fn small_matrix() -> ScenarioMatrix {
    ScenarioMatrix {
        platforms: vec!["has-gpu".to_string(), "kserve".to_string()],
        presets: vec![Preset::Standard],
        seeds: vec![5, 6],
        seconds: 60,
        gpus: 6,
        rps: 60.0,
        ..ScenarioMatrix::default()
    }
}

#[test]
fn deterministic_across_job_counts() {
    let matrix = small_matrix();
    let serial = matrix.run(1);
    let parallel = matrix.run(4);
    // The whole export — per-cell metrics, summary, ratios — must be
    // byte-identical: cells are pure functions of their coordinates.
    assert_eq!(
        serial.to_json().to_string_pretty(),
        parallel.to_json().to_string_pretty()
    );
    // Equal fingerprints ⇔ byte-identical exports (what the CI smoke job
    // asserts from the CLI side).
    assert_eq!(
        json::fingerprint(&serial.to_json()),
        json::fingerprint(&parallel.to_json())
    );
}

#[test]
fn grid_covers_every_cell_with_live_metrics() {
    let matrix = small_matrix();
    let report = matrix.run(2);
    assert_eq!(report.cells.len(), 4);
    for cell in &report.cells {
        assert!(cell.served > 0, "{} seed {} served nothing", cell.platform, cell.seed);
        assert!(cell.total_cost > 0.0);
        assert!(cell.p99_latency.is_finite());
    }
    // Both platforms present, and KServe's whole-GPU billing costs more in
    // aggregate (the Fig. 7 ordering).
    let cost = |p: &str| -> f64 {
        report
            .cells
            .iter()
            .filter(|c| c.platform == p)
            .map(|c| c.total_cost)
            .sum()
    };
    assert!(cost("kserve") > cost("has-gpu"));
    // Summary has one row per (preset, platform) and averages both seeds.
    let summary = report.summary();
    assert_eq!(summary.len(), 2);
    assert!(summary.iter().all(|r| r.cells == 2));
}

#[test]
fn bench_sim_json_roundtrips_through_util_json() {
    let report = small_matrix().run(2);
    let text = report.to_json().to_string_pretty();
    let parsed = json::parse(&text).expect("export is valid JSON");
    let back = MatrixReport::from_json(&parsed).expect("schema round-trips");
    assert_eq!(back, report);
    assert_eq!(back.to_json().to_string_pretty(), text);
}
