//! Property-based tests over the allocation substrate and the scaling
//! policies: no operation sequence may break the vGPU/cluster invariants
//! (SM ≤ 100%, alignment-class bound, per-slot quota ≤ 100%, placement
//! consistency), and the hybrid autoscaler must converge rather than
//! oscillate on steady workloads.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, KalmanFilter, ScalingPolicy};
use has_gpu::cluster::{Applied, ClusterState, FunctionSpec, GpuId, Reconfigurator, ScalingAction};
use has_gpu::metrics::{BillingLedger, BillingMode, HOST_CACHED_RATE};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::util::prng::Pcg64;
use has_gpu::util::proptest::{run_prop, PropConfig};
use has_gpu::vgpu::{
    ClientId, GpuClass, VGpu, MAX_SM_CLASSES, QUOTA_FULL, QUOTA_STEP, SM_FULL, SM_STEP,
};

#[test]
fn prop_vgpu_invariants_hold_under_random_ops() {
    run_prop("vgpu-random-ops", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-prop", 16e9);
        let mut live: Vec<ClientId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..size * 4 {
            match rng.next_below(4) {
                0 | 1 => {
                    // Attach with random aligned/unaligned sm + quota.
                    let sm = (rng.next_below(21) as u32) * SM_STEP;
                    let quota = (rng.next_below(10) as u32 + 1) * 100;
                    let mem = rng.uniform(0.1e9, 2.0e9);
                    next_id += 1;
                    let id = ClientId(next_id);
                    if gpu.attach(id, sm, quota, mem).is_ok() {
                        live.push(id);
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let idx = rng.next_below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        gpu.detach(id, 0.5e9).map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.next_below(live.len() as u64) as usize;
                        let quota = (rng.next_below(10) as u32 + 1) * 100;
                        let _ = gpu.set_quota(live[idx], quota);
                    }
                }
            }
            gpu.check_invariants()?;
            // HGO stays in [0, 1].
            let hgo = gpu.hgo();
            if !(0.0..=1.0 + 1e-9).contains(&hgo) {
                return Err(format!("hgo out of range: {hgo}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_no_false_negative_admission() {
    // If admissible() said yes, attach() must succeed (no fragmentation traps).
    run_prop("admission-consistent", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-adm", 16e9);
        let mut next_id = 0u64;
        for _ in 0..size * 3 {
            let sm = (rng.next_below(20) as u32 + 1) * SM_STEP;
            let quota = (rng.next_below(10) as u32 + 1) * 100;
            let ok = gpu.admissible(sm, quota).is_ok();
            next_id += 1;
            let attached = gpu.attach(ClientId(next_id), sm, quota, 0.0).is_ok();
            if ok != attached {
                return Err(format!(
                    "admissible={ok} but attach={attached} (sm={sm} q={quota})"
                ));
            }
            gpu.check_invariants()?;
        }
        Ok(())
    });
}

#[test]
fn prop_max_avail_quota_is_actually_available() {
    run_prop("max-avail-quota", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-q", 16e9);
        let mut live = Vec::new();
        for i in 0..size as u64 {
            let sm = (rng.next_below(4) as u32 + 1) * 250;
            let quota = (rng.next_below(5) as u32 + 1) * 100;
            if gpu.attach(ClientId(i), sm, quota, 0.0).is_ok() {
                live.push(ClientId(i));
            }
        }
        for &id in &live {
            let max_q = gpu.max_avail_quota(id).map_err(|e| e.to_string())?;
            if max_q > QUOTA_FULL {
                return Err(format!("max quota {max_q} > 1000"));
            }
            gpu.set_quota(id, max_q).map_err(|e| e.to_string())?;
            gpu.check_invariants()?;
            // One step above must fail.
            if max_q + 100 <= QUOTA_FULL && gpu.set_quota(id, max_q + 100).is_ok() {
                return Err("set_quota above max succeeded".into());
            }
            gpu.set_quota(id, 100).map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

fn spec() -> FunctionSpec {
    FunctionSpec {
        name: "resnet50".into(),
        graph: zoo_graph(ZooModel::ResNet50),
        slo: 0.25,
        batch: 8,
        artifact: None,
    }
}

#[test]
fn prop_autoscaler_actions_always_applicable() {
    // Whatever demand sequence arrives, the actions the hybrid scaler plans
    // against a consistent snapshot must apply cleanly and keep invariants.
    run_prop(
        "autoscaler-applicable",
        PropConfig {
            cases: 64,
            ..Default::default()
        },
        |rng, size| {
            let mut cluster = ClusterState::new(4, 16e9);
            cluster.register_function(spec());
            let mut recon = Reconfigurator::new(&cluster, 9);
            let pm = PerfModel::default();
            let pred = OraclePredictor::default();
            let mut scaler = HybridAutoscaler::new(HybridConfig::default());
            let mut now = 0.0;
            for _ in 0..size * 2 {
                now += 1.0;
                let demand = rng.uniform(0.0, 600.0);
                let actions = scaler.plan(&spec(), demand, &cluster, &pred, now);
                for a in &actions {
                    recon
                        .apply(&mut cluster, &pm, a, now)
                        .map_err(|e| format!("action {a:?} failed: {e}"))?;
                }
                cluster.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kalman_estimate_bounded_by_signal_range() {
    run_prop("kalman-bounded", PropConfig::default(), |rng, size| {
        let mut kf = KalmanFilter::new(2.0, 9.0);
        let lo = rng.uniform(0.0, 50.0);
        let hi = lo + rng.uniform(1.0, 100.0);
        for _ in 0..size * 5 {
            let obs = rng.uniform(lo, hi);
            let est = kf.update(obs);
            if est < 0.0 || est > hi * 1.05 + 1.0 {
                return Err(format!("estimate {est} outside [{lo},{hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn autoscaler_converges_on_steady_load() {
    // Steady demand ⇒ after warm-up the scaler should go quiet (hysteresis),
    // not thrash between up and down.
    let mut cluster = ClusterState::new(6, 16e9);
    cluster.register_function(spec());
    let mut recon = Reconfigurator::new(&cluster, 5);
    let pm = PerfModel::default();
    let pred = OraclePredictor::default();
    let mut scaler = HybridAutoscaler::new(HybridConfig::default());
    let demand = 120.0;
    let mut actions_late = 0;
    for t in 0..300 {
        let actions = scaler.plan(&spec(), demand, &cluster, &pred, t as f64);
        for a in &actions {
            let _ = recon.apply(&mut cluster, &pm, a, t as f64);
        }
        if t > 100 {
            actions_late += actions.len();
        }
    }
    cluster.check_invariants().unwrap();
    assert!(
        actions_late <= 4,
        "scaler still thrashing after warm-up: {actions_late} actions"
    );
    // And capacity covers demand.
    let cap: f64 = cluster
        .pods_of("resnet50")
        .iter()
        .map(|p| {
            pred_capacity(&pred, p.batch, p.sm, p.quota)
        })
        .sum();
    assert!(cap >= demand, "converged capacity {cap} < demand {demand}");
}

fn pred_capacity(
    pred: &OraclePredictor,
    batch: u32,
    sm: has_gpu::vgpu::SmMille,
    quota: has_gpu::vgpu::QuotaMille,
) -> f64 {
    use has_gpu::rapp::{LatencyPredictor, PredictQuery};
    pred.capacity(PredictQuery::new(
        &zoo_graph(ZooModel::ResNet50),
        batch,
        has_gpu::vgpu::sm_to_f64(sm),
        has_gpu::vgpu::quota_to_f64(quota),
    ))
}

// ---- Heterogeneous-fleet properties (GpuClass catalog) -------------------

/// A random fleet of 2–5 GPUs drawn from the catalog (at least two distinct
/// classes whenever size allows, so the heterogeneity is real).
fn random_fleet(rng: &mut Pcg64) -> Vec<GpuClass> {
    let catalog = GpuClass::catalog();
    let n = 2 + rng.next_below(4) as usize;
    let mut fleet: Vec<GpuClass> = (0..n)
        .map(|_| catalog[rng.next_below(catalog.len() as u64) as usize].clone())
        .collect();
    if fleet.iter().all(|c| c.name == fleet[0].name) {
        let other = catalog
            .iter()
            .find(|c| c.name != fleet[0].name)
            .unwrap()
            .clone();
        fleet[0] = other;
    }
    fleet
}

fn mixed_spec() -> FunctionSpec {
    FunctionSpec {
        name: "mobilenetv2".into(),
        graph: zoo_graph(ZooModel::MobileNetV2),
        slo: 0.25,
        batch: 1,
        artifact: None,
    }
}

/// One random raw scaling action against the current pod set, including the
/// lifecycle edges (demote to the host tier / promote back). Rejections
/// (alignment/capacity/memory races, illegal state transitions) are part of
/// the property: they must leave every invariant intact.
fn random_action(
    rng: &mut Pcg64,
    spec: &FunctionSpec,
    n_gpus: usize,
    live: &[has_gpu::cluster::PodId],
) -> Option<ScalingAction> {
    let pick = |rng: &mut Pcg64| live[rng.next_below(live.len() as u64) as usize];
    match rng.next_below(5) {
        0 => Some(ScalingAction::CreatePod {
            function: spec.name.clone(),
            gpu: GpuId(rng.next_below(n_gpus as u64) as usize),
            sm: SM_STEP * (1 + rng.next_below(20) as u32),
            quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
            batch: spec.batch,
            new_gpu: false,
        }),
        1 if !live.is_empty() => Some(ScalingAction::SetQuota {
            pod: pick(rng),
            quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
        }),
        2 if !live.is_empty() => Some(ScalingAction::DemotePod { pod: pick(rng) }),
        3 if !live.is_empty() => Some(ScalingAction::PromotePod { pod: pick(rng) }),
        _ if !live.is_empty() => Some(ScalingAction::RemovePod { pod: pick(rng) }),
        _ => None,
    }
}

#[test]
fn prop_mixed_fleet_invariants_hold_under_random_actions() {
    // The ISSUE's invariant list, asserted explicitly per step on random
    // heterogeneous fleets: Σ slot SM ≤ 1000 per GPU, Σ quota ≤ 1000 per
    // slot, ≤ MAX_SM_CLASSES partition classes, per-class memory caps
    // respected — plus the cluster-wide placement-consistency check.
    run_prop(
        "mixed-fleet-invariants",
        PropConfig {
            cases: 96,
            max_size: 48,
            ..PropConfig::default()
        },
        |rng, size| {
            let fleet = random_fleet(rng);
            let spec = mixed_spec();
            let perf = PerfModel::default();
            let mut cluster = ClusterState::from_classes(&fleet);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 13);
            let mut live: Vec<has_gpu::cluster::PodId> = Vec::new();
            for step in 0..size * 2 {
                let Some(action) = random_action(rng, &spec, fleet.len(), &live) else {
                    continue;
                };
                match recon.apply(&mut cluster, &perf, &action, step as f64) {
                    Ok(Applied::PodCreated { pod, .. }) => live.push(pod),
                    Ok(Applied::PodRemoved { pod }) => live.retain(|&p| p != pod),
                    Ok(_) | Err(_) => {}
                }
                cluster.check_invariants()?;
                for i in 0..cluster.n_gpus() {
                    let g = cluster.gpu(GpuId(i));
                    has_gpu::prop_assert!(
                        g.sm_allocated() <= SM_FULL,
                        "step {step}: GPU {i} over-allocated: {}‰",
                        g.sm_allocated()
                    );
                    has_gpu::prop_assert!(
                        g.sm_classes().len() <= MAX_SM_CLASSES,
                        "step {step}: GPU {i} classes {:?}",
                        g.sm_classes()
                    );
                    for (si, slot) in g.slots().iter().enumerate() {
                        has_gpu::prop_assert!(
                            slot.quota_used() <= QUOTA_FULL,
                            "step {step}: GPU {i} slot {si} quota {}‰",
                            slot.quota_used()
                        );
                    }
                    // Per-class memory cap: accounting never exceeds the
                    // *device's own* class capacity.
                    has_gpu::prop_assert!(
                        g.mem_free() >= -1.0,
                        "step {step}: GPU {i} ({}) over-committed memory",
                        g.class().name
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pod_lifecycle_transitions_are_always_legal() {
    // Random action sequences — creates, quota rewrites, demotions,
    // promotions, removals, with rejections in the mix — may only ever move
    // a pod along the legal state machine (`Cold → HostCached ⇄
    // DeviceResident`), a rejected action must leave every pod's state (and
    // keep-alive clock) untouched, and the cluster invariants must hold
    // throughout. Runs under the swap-tier perf model so the lifecycle
    // latencies are real.
    use has_gpu::cluster::PodState;
    run_prop(
        "pod-lifecycle-legal",
        PropConfig {
            cases: 96,
            max_size: 48,
            ..PropConfig::default()
        },
        |rng, size| {
            let fleet = random_fleet(rng);
            let spec = mixed_spec();
            let perf = PerfModel::with_swap_tier();
            let mut cluster = ClusterState::from_classes(&fleet);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 17);
            let mut live: Vec<has_gpu::cluster::PodId> = Vec::new();
            let snapshot = |cluster: &ClusterState| -> std::collections::BTreeMap<_, _> {
                cluster
                    .pods_of(&spec.name)
                    .iter()
                    .map(|p| (p.id, (p.state, p.state_since)))
                    .collect()
            };
            for step in 0..size * 2 {
                let now = step as f64;
                let Some(action) = random_action(rng, &spec, fleet.len(), &live) else {
                    continue;
                };
                let before = snapshot(&cluster);
                let outcome = recon.apply(&mut cluster, &perf, &action, now);
                let after = snapshot(&cluster);
                for (id, (new_state, new_since)) in &after {
                    match before.get(id) {
                        // Surviving pods: unchanged, or one legal edge with
                        // the keep-alive clock restamped to now.
                        Some((old_state, old_since)) => {
                            if new_state == old_state {
                                has_gpu::prop_assert!(
                                    new_since == old_since,
                                    "step {step}: {id:?} clock moved without a transition"
                                );
                            } else {
                                has_gpu::prop_assert!(
                                    old_state.can_transition(*new_state),
                                    "step {step}: illegal transition {} -> {} on {id:?}",
                                    old_state.name(),
                                    new_state.name()
                                );
                                has_gpu::prop_assert!(
                                    (*new_since - now).abs() < 1e-12,
                                    "step {step}: transition did not restamp state_since"
                                );
                            }
                        }
                        // Births start device-resident (the swap tier delays
                        // readiness via ready_at, never via a Cold state).
                        None => has_gpu::prop_assert!(
                            *new_state == PodState::DeviceResident,
                            "step {step}: {id:?} born {}",
                            new_state.name()
                        ),
                    }
                }
                match outcome {
                    Ok(Applied::PodCreated { pod, .. }) => live.push(pod),
                    Ok(Applied::PodRemoved { pod }) => live.retain(|&p| p != pod),
                    Ok(_) => {}
                    // Rejections must be pure no-ops on the state machine.
                    Err(_) => has_gpu::prop_assert!(
                        before == after,
                        "step {step}: rejected {action:?} still mutated pod states"
                    ),
                }
                cluster.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mixed_fleet_ledger_matches_per_class_slice_time_integral() {
    // For random heterogeneous action sequences — now including demotions
    // to the host tier and promotions back — the ledger must equal the
    // analytic per-class, per-state slice-time integral: resident intervals
    // at the full slice rate, parked intervals at `HOST_CACHED_RATE`, per
    // class AND in total, in BOTH billing modes, with each pod priced at
    // its class's effective rate (reference price × catalog ratio), exactly
    // as `record_applied` prices real runs.
    const PRICE: f64 = 3600.0; // $1 per reference slice-second
    run_prop(
        "mixed-fleet-billing",
        PropConfig {
            cases: 64,
            max_size: 40,
            ..PropConfig::default()
        },
        |rng, size| {
            let fleet = random_fleet(rng);
            let spec = mixed_spec();
            let perf = PerfModel::default();
            let mut cluster = ClusterState::from_classes(&fleet);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 7);
            let mut fine = BillingLedger::new(BillingMode::FineGrained, PRICE);
            let mut whole = BillingLedger::new(BillingMode::WholeGpu, PRICE);
            // Live pods: (id, class name, price ratio, sm‰, q‰, resident).
            let mut live: Vec<(has_gpu::cluster::PodId, String, f64, u32, u32, bool)> =
                Vec::new();
            let mut fine_ref: std::collections::BTreeMap<String, f64> = Default::default();
            let mut whole_ref: std::collections::BTreeMap<String, f64> = Default::default();
            let mut accrue =
                |live: &[(has_gpu::cluster::PodId, String, f64, u32, u32, bool)],
                 fine_ref: &mut std::collections::BTreeMap<String, f64>,
                 whole_ref: &mut std::collections::BTreeMap<String, f64>,
                 dt: f64| {
                    for (_, class, ratio, sm, q, resident) in live {
                        let state = if *resident { 1.0 } else { HOST_CACHED_RATE };
                        *fine_ref.entry(class.clone()).or_insert(0.0) +=
                            (*sm as f64 / 1000.0) * state * (*q as f64 / 1000.0) * dt * ratio;
                        *whole_ref.entry(class.clone()).or_insert(0.0) += state * dt * ratio;
                    }
                };
            let mut now = 0.0f64;
            for _ in 0..size {
                let dt = rng.next_f64() * 3.0;
                accrue(&live, &mut fine_ref, &mut whole_ref, dt);
                now += dt;
                let live_ids: Vec<_> = live.iter().map(|(p, ..)| *p).collect();
                let Some(action) = random_action(rng, &spec, fleet.len(), &live_ids) else {
                    continue;
                };
                match recon.apply(&mut cluster, &perf, &action, now) {
                    Ok(Applied::PodCreated { pod, .. }) => {
                        let p = cluster.pod(pod).expect("created");
                        let class = cluster.gpu(p.gpu).class().clone();
                        let price = PRICE * class.price_relative();
                        fine.open_on(pod, &p.function, p.sm, p.quota, &class.name, price, now);
                        whole.open_on(pod, &p.function, p.sm, p.quota, &class.name, price, now);
                        live.push((
                            pod,
                            class.name.clone(),
                            class.price_relative(),
                            p.sm,
                            p.quota,
                            true,
                        ));
                    }
                    Ok(Applied::QuotaSet { pod, new, .. }) => {
                        fine.resize(pod, new, now);
                        whole.resize(pod, new, now);
                        let e = live.iter_mut().find(|(p, ..)| *p == pod).unwrap();
                        e.4 = new;
                    }
                    Ok(Applied::PodDemoted { pod }) => {
                        fine.set_resident(pod, false, now);
                        whole.set_resident(pod, false, now);
                        let e = live.iter_mut().find(|(p, ..)| *p == pod).unwrap();
                        e.5 = false;
                    }
                    Ok(Applied::PodPromoted { pod, .. }) => {
                        fine.set_resident(pod, true, now);
                        whole.set_resident(pod, true, now);
                        let e = live.iter_mut().find(|(p, ..)| *p == pod).unwrap();
                        e.5 = true;
                    }
                    Ok(Applied::PodRemoved { pod }) => {
                        fine.close(pod, now);
                        whole.close(pod, now);
                        live.retain(|(p, ..)| *p != pod);
                    }
                    Err(_) => {}
                }
            }
            let t_end = now + rng.next_f64() * 2.0;
            accrue(&live, &mut fine_ref, &mut whole_ref, t_end - now);
            let fine_meter = fine.into_meter(t_end);
            let whole_meter = whole.into_meter(t_end);
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * (1.0 + a.abs().max(b.abs()));
            for (refs, meter, label) in [
                (&fine_ref, &fine_meter, "fine-grained"),
                (&whole_ref, &whole_meter, "whole-gpu"),
            ] {
                for (class, &expect) in refs {
                    has_gpu::prop_assert!(
                        close(meter.class_cost_of(class), expect),
                        "{label} class {class}: ledger {} vs analytic {expect}",
                        meter.class_cost_of(class)
                    );
                }
                let total_ref: f64 = refs.values().sum();
                has_gpu::prop_assert!(
                    close(meter.total_cost(), total_ref),
                    "{label} total: ledger {} vs analytic {total_ref}",
                    meter.total_cost()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hybrid_plan_actions_applicable_on_mixed_fleets() {
    // Whatever demand arrives, the class-aware hybrid scaler's actions must
    // apply cleanly on random heterogeneous fleets and keep every
    // invariant — the mixed-fleet extension of the homogeneous
    // `prop_autoscaler_actions_always_applicable`.
    run_prop(
        "mixed-fleet-autoscaler",
        PropConfig {
            cases: 48,
            max_size: 48,
            ..Default::default()
        },
        |rng, size| {
            let fleet = random_fleet(rng);
            let spec = spec();
            let mut cluster = ClusterState::from_classes(&fleet);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 21);
            let pm = PerfModel::default();
            let pred = OraclePredictor::default();
            let mut scaler = HybridAutoscaler::new(HybridConfig::default());
            let mut now = 0.0;
            for _ in 0..size * 2 {
                now += 1.0;
                let demand = rng.uniform(0.0, 600.0);
                let actions = scaler.plan(&spec, demand, &cluster, &pred, now);
                for a in &actions {
                    recon
                        .apply(&mut cluster, &pm, a, now)
                        .map_err(|e| format!("fleet {fleet:?}: action {a:?} failed: {e}"))?;
                }
                cluster.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn sm_alignment_prevents_fragmentation_scenario() {
    // Fig. 2's fragmentation scenario: interleaved odd-size allocations.
    // With alignment, the GPU either packs them into existing classes or
    // rejects cleanly — free SM stays allocatable for any existing class.
    let mut gpu = VGpu::new("GPU-frag", 16e9);
    let mut id = 0u64;
    let mut attach = |gpu: &mut VGpu, sm: u32, q: u32| {
        id += 1;
        gpu.attach(ClientId(id), sm, q, 0.0)
    };
    attach(&mut gpu, 300, 500).unwrap();
    attach(&mut gpu, 200, 500).unwrap();
    attach(&mut gpu, 100, 500).unwrap();
    // 400‰ free; any *existing* class must still fit.
    for class in gpu.sm_classes() {
        assert!(
            gpu.admissible(class, 400).is_ok(),
            "class {class} not placeable despite {}‰ free",
            gpu.sm_free()
        );
    }
    gpu.check_invariants().unwrap();
}

#[test]
fn scaling_action_counts_match_cluster_mutation() {
    let mut cluster = ClusterState::new(2, 16e9);
    cluster.register_function(spec());
    let mut recon = Reconfigurator::new(&cluster, 5);
    let pm = PerfModel::default();
    let a = ScalingAction::CreatePod {
        function: "resnet50".into(),
        gpu: GpuId(0),
        sm: 500,
        quota: 500,
        batch: 8,
        new_gpu: true,
    };
    recon.apply(&mut cluster, &pm, &a, 0.0).unwrap();
    assert_eq!(cluster.pods_of("resnet50").len(), 1);
    assert_eq!(cluster.gpus_in_use(), 1);
}
