//! Property-based tests over the allocation substrate and the scaling
//! policies: no operation sequence may break the vGPU/cluster invariants
//! (SM ≤ 100%, alignment-class bound, per-slot quota ≤ 100%, placement
//! consistency), and the hybrid autoscaler must converge rather than
//! oscillate on steady workloads.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, KalmanFilter, ScalingPolicy};
use has_gpu::cluster::{ClusterState, FunctionSpec, GpuId, Reconfigurator, ScalingAction};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::util::proptest::{run_prop, PropConfig};
use has_gpu::vgpu::{ClientId, VGpu, QUOTA_FULL, SM_FULL, SM_STEP};

#[test]
fn prop_vgpu_invariants_hold_under_random_ops() {
    run_prop("vgpu-random-ops", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-prop", 16e9);
        let mut live: Vec<ClientId> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..size * 4 {
            match rng.next_below(4) {
                0 | 1 => {
                    // Attach with random aligned/unaligned sm + quota.
                    let sm = (rng.next_below(21) as u32) * SM_STEP;
                    let quota = (rng.next_below(10) as u32 + 1) * 100;
                    let mem = rng.uniform(0.1e9, 2.0e9);
                    next_id += 1;
                    let id = ClientId(next_id);
                    if gpu.attach(id, sm, quota, mem).is_ok() {
                        live.push(id);
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let idx = rng.next_below(live.len() as u64) as usize;
                        let id = live.swap_remove(idx);
                        gpu.detach(id, 0.5e9).map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.next_below(live.len() as u64) as usize;
                        let quota = (rng.next_below(10) as u32 + 1) * 100;
                        let _ = gpu.set_quota(live[idx], quota);
                    }
                }
            }
            gpu.check_invariants()?;
            // HGO stays in [0, 1].
            let hgo = gpu.hgo();
            if !(0.0..=1.0 + 1e-9).contains(&hgo) {
                return Err(format!("hgo out of range: {hgo}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_no_false_negative_admission() {
    // If admissible() said yes, attach() must succeed (no fragmentation traps).
    run_prop("admission-consistent", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-adm", 16e9);
        let mut next_id = 0u64;
        for _ in 0..size * 3 {
            let sm = (rng.next_below(20) as u32 + 1) * SM_STEP;
            let quota = (rng.next_below(10) as u32 + 1) * 100;
            let ok = gpu.admissible(sm, quota).is_ok();
            next_id += 1;
            let attached = gpu.attach(ClientId(next_id), sm, quota, 0.0).is_ok();
            if ok != attached {
                return Err(format!(
                    "admissible={ok} but attach={attached} (sm={sm} q={quota})"
                ));
            }
            gpu.check_invariants()?;
        }
        Ok(())
    });
}

#[test]
fn prop_max_avail_quota_is_actually_available() {
    run_prop("max-avail-quota", PropConfig::default(), |rng, size| {
        let mut gpu = VGpu::new("GPU-q", 16e9);
        let mut live = Vec::new();
        for i in 0..size as u64 {
            let sm = (rng.next_below(4) as u32 + 1) * 250;
            let quota = (rng.next_below(5) as u32 + 1) * 100;
            if gpu.attach(ClientId(i), sm, quota, 0.0).is_ok() {
                live.push(ClientId(i));
            }
        }
        for &id in &live {
            let max_q = gpu.max_avail_quota(id).map_err(|e| e.to_string())?;
            if max_q > QUOTA_FULL {
                return Err(format!("max quota {max_q} > 1000"));
            }
            gpu.set_quota(id, max_q).map_err(|e| e.to_string())?;
            gpu.check_invariants()?;
            // One step above must fail.
            if max_q + 100 <= QUOTA_FULL && gpu.set_quota(id, max_q + 100).is_ok() {
                return Err("set_quota above max succeeded".into());
            }
            gpu.set_quota(id, 100).map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

fn spec() -> FunctionSpec {
    FunctionSpec {
        name: "resnet50".into(),
        graph: zoo_graph(ZooModel::ResNet50),
        slo: 0.25,
        batch: 8,
        artifact: None,
    }
}

#[test]
fn prop_autoscaler_actions_always_applicable() {
    // Whatever demand sequence arrives, the actions the hybrid scaler plans
    // against a consistent snapshot must apply cleanly and keep invariants.
    run_prop(
        "autoscaler-applicable",
        PropConfig {
            cases: 64,
            ..Default::default()
        },
        |rng, size| {
            let mut cluster = ClusterState::new(4, 16e9);
            cluster.register_function(spec());
            let mut recon = Reconfigurator::new(&cluster, 9);
            let pm = PerfModel::default();
            let pred = OraclePredictor::default();
            let mut scaler = HybridAutoscaler::new(HybridConfig::default());
            let mut now = 0.0;
            for _ in 0..size * 2 {
                now += 1.0;
                let demand = rng.uniform(0.0, 600.0);
                let actions = scaler.plan(&spec(), demand, &cluster, &pred, now);
                for a in &actions {
                    recon
                        .apply(&mut cluster, &pm, a, now)
                        .map_err(|e| format!("action {a:?} failed: {e}"))?;
                }
                cluster.check_invariants()?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kalman_estimate_bounded_by_signal_range() {
    run_prop("kalman-bounded", PropConfig::default(), |rng, size| {
        let mut kf = KalmanFilter::new(2.0, 9.0);
        let lo = rng.uniform(0.0, 50.0);
        let hi = lo + rng.uniform(1.0, 100.0);
        for _ in 0..size * 5 {
            let obs = rng.uniform(lo, hi);
            let est = kf.update(obs);
            if est < 0.0 || est > hi * 1.05 + 1.0 {
                return Err(format!("estimate {est} outside [{lo},{hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn autoscaler_converges_on_steady_load() {
    // Steady demand ⇒ after warm-up the scaler should go quiet (hysteresis),
    // not thrash between up and down.
    let mut cluster = ClusterState::new(6, 16e9);
    cluster.register_function(spec());
    let mut recon = Reconfigurator::new(&cluster, 5);
    let pm = PerfModel::default();
    let pred = OraclePredictor::default();
    let mut scaler = HybridAutoscaler::new(HybridConfig::default());
    let demand = 120.0;
    let mut actions_late = 0;
    for t in 0..300 {
        let actions = scaler.plan(&spec(), demand, &cluster, &pred, t as f64);
        for a in &actions {
            let _ = recon.apply(&mut cluster, &pm, a, t as f64);
        }
        if t > 100 {
            actions_late += actions.len();
        }
    }
    cluster.check_invariants().unwrap();
    assert!(
        actions_late <= 4,
        "scaler still thrashing after warm-up: {actions_late} actions"
    );
    // And capacity covers demand.
    let cap: f64 = cluster
        .pods_of("resnet50")
        .iter()
        .map(|p| {
            pred_capacity(&pred, p.batch, p.sm, p.quota)
        })
        .sum();
    assert!(cap >= demand, "converged capacity {cap} < demand {demand}");
}

fn pred_capacity(
    pred: &OraclePredictor,
    batch: u32,
    sm: has_gpu::vgpu::SmMille,
    quota: has_gpu::vgpu::QuotaMille,
) -> f64 {
    use has_gpu::rapp::LatencyPredictor;
    pred.capacity(
        &zoo_graph(ZooModel::ResNet50),
        batch,
        has_gpu::vgpu::sm_to_f64(sm),
        has_gpu::vgpu::quota_to_f64(quota),
    )
}

#[test]
fn sm_alignment_prevents_fragmentation_scenario() {
    // Fig. 2's fragmentation scenario: interleaved odd-size allocations.
    // With alignment, the GPU either packs them into existing classes or
    // rejects cleanly — free SM stays allocatable for any existing class.
    let mut gpu = VGpu::new("GPU-frag", 16e9);
    let mut id = 0u64;
    let mut attach = |gpu: &mut VGpu, sm: u32, q: u32| {
        id += 1;
        gpu.attach(ClientId(id), sm, q, 0.0)
    };
    attach(&mut gpu, 300, 500).unwrap();
    attach(&mut gpu, 200, 500).unwrap();
    attach(&mut gpu, 100, 500).unwrap();
    // 400‰ free; any *existing* class must still fit.
    for class in gpu.sm_classes() {
        assert!(
            gpu.admissible(class, 400).is_ok(),
            "class {class} not placeable despite {}‰ free",
            gpu.sm_free()
        );
    }
    gpu.check_invariants().unwrap();
}

#[test]
fn scaling_action_counts_match_cluster_mutation() {
    let mut cluster = ClusterState::new(2, 16e9);
    cluster.register_function(spec());
    let mut recon = Reconfigurator::new(&cluster, 5);
    let pm = PerfModel::default();
    let a = ScalingAction::CreatePod {
        function: "resnet50".into(),
        gpu: GpuId(0),
        sm: 500,
        quota: 500,
        batch: 8,
        new_gpu: true,
    };
    recon.apply(&mut cluster, &pm, &a, 0.0).unwrap();
    assert_eq!(cluster.pods_of("resnet50").len(), 1);
    assert_eq!(cluster.gpus_in_use(), 1);
}
