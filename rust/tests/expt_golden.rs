//! Golden byte-identity for the platform-registry redesign **and** the
//! GPU-class / fleet extension.
//!
//! The registry replaced the closed `expt::Platform` enum; the hard API
//! contract is that for the stock trio (`has-gpu`, `kserve`, `fast-gshare`)
//! the `BENCH_sim.json` export stays **byte-identical** to the enum-based
//! output. This test freezes the pre-redesign construction verbatim — the
//! enum's `match` arms for policy, billing mode, and predictor, and the
//! canonical preset-major cell walk — runs both paths on the same grid, and
//! compares the full pretty-printed export byte for byte.
//!
//! The frozen path is doubly golden since the `GpuClass` catalog landed: it
//! still builds its clusters through the **pre-fleet homogeneous
//! constructor** (`ClusterState::new` inside `run_sim`'s empty-fleet path)
//! while the registry path routes every cell through
//! `FleetSpec::classes_for` + `ClusterState::from_classes` — so the byte
//! comparison also pins "`uniform-v100` is an extension, never a
//! perturbation".
//!
//! Two more contracts ride along: ablation platforms *extend* the grid
//! without perturbing the stock cells they share it with, and adding a
//! mixed fleet to the fleet axis perturbs no uniform cell.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::baselines::{FastGSharePolicy, KServePolicy};
use has_gpu::expt::{
    experiment_functions, CellResult, MatrixReport, ScenarioCell, ScenarioMatrix, DEFAULT_FLEET,
};
use has_gpu::metrics::BillingMode;
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::sim::{run_sim, SimConfig, NO_FAULTS};
use has_gpu::util::json;
use has_gpu::workload::{Preset, TraceGen};

const SECONDS: usize = 60;
const GPUS: usize = 6;
const RPS: f64 = 60.0;
const SEEDS: [u64; 2] = [5, 6];

/// Verbatim freeze of the closed enum the registry replaced: name table,
/// policy `match`, and billing rule exactly as `expt::Platform` had them.
#[derive(Clone, Copy)]
enum FrozenPlatform {
    HasGpu,
    KServe,
    FastGShare,
}

const FROZEN_ALL: [FrozenPlatform; 3] = [
    FrozenPlatform::HasGpu,
    FrozenPlatform::KServe,
    FrozenPlatform::FastGShare,
];

impl FrozenPlatform {
    fn name(self) -> &'static str {
        match self {
            FrozenPlatform::HasGpu => "has-gpu",
            FrozenPlatform::KServe => "kserve",
            FrozenPlatform::FastGShare => "fast-gshare",
        }
    }

    fn policy(self) -> Box<dyn ScalingPolicy> {
        match self {
            FrozenPlatform::HasGpu => Box::new(HybridAutoscaler::new(HybridConfig::default())),
            FrozenPlatform::KServe => Box::new(KServePolicy::default()),
            FrozenPlatform::FastGShare => Box::new(FastGSharePolicy::default()),
        }
    }

    fn bill_whole_gpu(self) -> bool {
        matches!(self, FrozenPlatform::KServe)
    }
}

/// The pre-redesign grid runner: canonical preset-major / platform / seed
/// order, per-cell construction exactly as the enum-era `run_cell` had it
/// (oracle predictor, fresh policy from the `match`, billing from the
/// enum's whole-GPU rule).
fn frozen_run(presets: &[Preset]) -> MatrixReport {
    let mut cells = Vec::new();
    for &preset in presets {
        for platform in FROZEN_ALL {
            for &seed in &SEEDS {
                let fns = experiment_functions();
                let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
                let trace = TraceGen::preset(preset, seed, SECONDS, RPS).generate(&names);
                let perf = PerfModel::default();
                let predictor = OraclePredictor::default();
                let mut policy = platform.policy();
                let report = run_sim(
                    policy.as_mut(),
                    &fns,
                    &trace,
                    &predictor,
                    &perf,
                    &SimConfig::for_experiment(
                        GPUS,
                        seed,
                        BillingMode::from_whole_gpu(platform.bill_whole_gpu()),
                    ),
                );
                let cell = ScenarioCell {
                    platform: platform.name().to_string(),
                    preset,
                    seed,
                    fleet: DEFAULT_FLEET.to_string(),
                    fault: NO_FAULTS.to_string(),
                };
                cells.push(CellResult::from_report(&cell, &fns, &report));
            }
        }
    }
    MatrixReport {
        seconds: SECONDS,
        gpus: GPUS,
        rps: RPS,
        fleets: vec![DEFAULT_FLEET.to_string()],
        faults: vec![NO_FAULTS.to_string()],
        cells,
    }
}

fn registry_matrix(platforms: &[&str]) -> ScenarioMatrix {
    ScenarioMatrix {
        platforms: platforms.iter().map(|s| s.to_string()).collect(),
        presets: vec![Preset::Standard],
        seeds: SEEDS.to_vec(),
        seconds: SECONDS,
        gpus: GPUS,
        rps: RPS,
        ..ScenarioMatrix::default()
    }
}

#[test]
fn stock_trio_export_is_byte_identical_to_the_enum_era_path() {
    let golden = frozen_run(&[Preset::Standard]).to_json().to_string_pretty();
    let via_registry = registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
        .run(2)
        .to_json()
        .to_string_pretty();
    assert_eq!(
        golden, via_registry,
        "stock-trio BENCH_sim.json must not change under the registry redesign"
    );
}

#[test]
fn ablation_platforms_extend_the_grid_without_perturbing_stock_cells() {
    let trio = registry_matrix(&["has-gpu", "kserve", "fast-gshare"]).run(2);
    let extended =
        registry_matrix(&["has-gpu", "kserve", "fast-gshare", "has-vertical-only"]).run(2);
    // The ablation rides along…
    assert_eq!(extended.cells.len(), trio.cells.len() + SEEDS.len());
    assert!(extended
        .cells
        .iter()
        .any(|c| c.platform == "has-vertical-only"));
    // …and every stock cell it shares with the trio grid is identical,
    // byte for byte, in the canonical order.
    let stock: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.platform != "has-vertical-only")
        .collect();
    assert_eq!(stock.len(), trio.cells.len());
    for (a, b) in trio.cells.iter().zip(stock) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "stock cell ({}, {}, {}) perturbed by ablation extension",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // Stock summary rows are identical too (the ablation only appends).
    let trio_summary = trio.summary();
    let ext_summary: Vec<_> = extended
        .summary()
        .into_iter()
        .filter(|r| r.platform != "has-vertical-only")
        .collect();
    assert_eq!(trio_summary, ext_summary);
    // And the trio fingerprint is reproducible run-to-run (what the CI
    // smoke job asserts across --jobs values).
    let again = registry_matrix(&["has-gpu", "kserve", "fast-gshare"]).run(1);
    assert_eq!(
        json::fingerprint(&trio.to_json()),
        json::fingerprint(&again.to_json())
    );
}

fn fleet_matrix(fleets: &[&str]) -> ScenarioMatrix {
    ScenarioMatrix {
        fleets: fleets.iter().map(|s| s.to_string()).collect(),
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    }
}

#[test]
fn mixed_fleet_extension_perturbs_no_uniform_cells() {
    // The heterogeneity contract: adding a mixed fleet to the grid's fleet
    // axis leaves every uniform-v100 cell — and the summary rows derived
    // from them — byte-identical, while the mixed cells run end-to-end
    // with per-class columns.
    let uniform = fleet_matrix(&[DEFAULT_FLEET]).run(2);
    let extended = fleet_matrix(&[DEFAULT_FLEET, "mixed-a100-v100-t4"]).run(2);
    assert_eq!(extended.cells.len(), uniform.cells.len() * 2);
    // Uniform cells are the byte-identical prefix (fleet-major cell order).
    let uni_cells: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.fleet == DEFAULT_FLEET)
        .collect();
    assert_eq!(uni_cells.len(), uniform.cells.len());
    for (a, b) in uniform.cells.iter().zip(uni_cells) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "uniform cell ({}, {}, {}) perturbed by the mixed fleet",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // Uniform summary rows are identical too.
    let uni_summary: Vec<_> = extended
        .summary()
        .into_iter()
        .filter(|r| r.fleet == DEFAULT_FLEET)
        .collect();
    assert_eq!(uniform.summary(), uni_summary);
    // The mixed cells actually ran: traffic served, per-class pricing in
    // the ledger (class costs sum to the cell total), every platform
    // represented.
    let mixed: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.fleet == "mixed-a100-v100-t4")
        .collect();
    assert_eq!(mixed.len(), uniform.cells.len());
    for c in &mixed {
        assert!(c.served > 0, "{} served nothing on the mixed fleet", c.platform);
        assert!(!c.classes.is_empty(), "{} exported no class columns", c.platform);
        let class_cost: f64 = c.classes.iter().map(|k| k.cost).sum();
        assert!(
            (class_cost - c.total_cost).abs() < 1e-9,
            "{}: class costs {class_cost} != total {}",
            c.platform,
            c.total_cost
        );
        let class_gpus: usize = c.classes.iter().map(|k| k.gpus).sum();
        assert_eq!(class_gpus, GPUS);
    }
    for p in ["has-gpu", "kserve", "fast-gshare"] {
        assert!(mixed.iter().any(|c| c.platform == p), "missing {p}");
    }
    // Headline ratios exist per fleet, and the whole fleet grid is --jobs
    // invariant (the CI fleet smoke's in-process twin).
    let ratios = extended.ratios_vs_has_gpu();
    assert!(ratios.iter().any(|r| r.fleet == "mixed-a100-v100-t4"));
    assert!(ratios.iter().any(|r| r.fleet == DEFAULT_FLEET));
    let again = fleet_matrix(&[DEFAULT_FLEET, "mixed-a100-v100-t4"]).run(1);
    assert_eq!(
        json::fingerprint(&extended.to_json()),
        json::fingerprint(&again.to_json())
    );
    // And the fleet export round-trips losslessly.
    let back = MatrixReport::from_json(&extended.to_json()).unwrap();
    assert_eq!(back, extended);
    assert_eq!(
        back.to_json().to_string_pretty(),
        extended.to_json().to_string_pretty()
    );
}

#[test]
fn lifecycle_extension_perturbs_no_stock_cells() {
    // The pod-lifecycle contract: adding the torpor-like swap tier and the
    // cold-start-storm preset to a grid leaves every pre-existing
    // (platform, standard, seed) cell byte-identical — default lifecycle
    // config (zero load/swap latency, warm start, infinite keep-alive) is
    // invisible to the export.
    let stock = registry_matrix(&["has-gpu", "kserve", "fast-gshare", "has-vertical-only"]).run(2);
    let extended = ScenarioMatrix {
        presets: vec![Preset::Standard, Preset::ColdStartStorm],
        ..registry_matrix(&[
            "has-gpu",
            "kserve",
            "fast-gshare",
            "has-vertical-only",
            "torpor-like",
        ])
    }
    .run(2);
    // 5 platforms × 2 presets × 2 seeds.
    assert_eq!(extended.cells.len(), 20);
    let shared: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.preset == Preset::Standard && c.platform != "torpor-like")
        .collect();
    assert_eq!(shared.len(), stock.cells.len());
    for (a, b) in stock.cells.iter().zip(shared) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "stock cell ({}, {}, {}) perturbed by the lifecycle extension",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // TTFT keys exist exactly on the lifecycle cells.
    for c in &extended.cells {
        let j = c.to_json();
        let has_ttft = j.opt("ttft_p50").is_some() && j.opt("ttft_p99").is_some();
        assert_eq!(
            has_ttft,
            c.preset == Preset::ColdStartStorm,
            "({}, {}, {}) ttft key presence",
            c.platform,
            c.preset.name(),
            c.seed
        );
    }
    // The extended grid round-trips losslessly and is --jobs invariant.
    let back = MatrixReport::from_json(&extended.to_json()).unwrap();
    assert_eq!(
        back.to_json().to_string_pretty(),
        extended.to_json().to_string_pretty()
    );
    let again = ScenarioMatrix {
        presets: vec![Preset::Standard, Preset::ColdStartStorm],
        ..registry_matrix(&[
            "has-gpu",
            "kserve",
            "fast-gshare",
            "has-vertical-only",
            "torpor-like",
        ])
    }
    .run(1);
    assert_eq!(
        json::fingerprint(&extended.to_json()),
        json::fingerprint(&again.to_json())
    );
}

#[test]
fn cold_start_storm_headline_directions() {
    // The paper-shaped outcome for the storm grid: HAS-GPU (hybrid scaling,
    // idle-margin floor keeps the last replica resident) beats the
    // torpor-like swap tier on tail TTFT, while the swap tier undercuts
    // always-on whole-GPU KServe on cost.
    let report = ScenarioMatrix {
        presets: vec![Preset::ColdStartStorm],
        seconds: 240,
        ..registry_matrix(&["has-gpu", "kserve", "torpor-like"])
    }
    .run(2);
    let summary = report.summary();
    let row = |p: &str| summary.iter().find(|r| r.platform == p).unwrap();
    let has = row("has-gpu");
    let torpor = row("torpor-like");
    let kserve = row("kserve");
    // Everyone actually served traffic through the storm.
    for r in [&has, &torpor, &kserve] {
        let served: usize = report
            .cells
            .iter()
            .filter(|c| c.platform == r.platform)
            .map(|c| c.served)
            .sum();
        assert!(served > 0, "{} served nothing", r.platform);
    }
    let (has_ttft, torpor_ttft) = (has.ttft_p99.unwrap(), torpor.ttft_p99.unwrap());
    assert!(
        has_ttft < torpor_ttft,
        "has-gpu ttft p99 {has_ttft} must beat torpor-like {torpor_ttft}"
    );
    assert!(
        torpor.cost_per_1k < kserve.cost_per_1k,
        "torpor-like $/1k {} must undercut kserve {}",
        torpor.cost_per_1k,
        kserve.cost_per_1k
    );
    // And the TTFT headline ratio materialises for the storm rows.
    let ratios = report.ratios_vs_has_gpu();
    let tr = ratios
        .iter()
        .find(|r| r.platform == "torpor-like")
        .and_then(|r| r.ttft_ratio)
        .unwrap();
    assert!(tr > 1.0, "torpor/has ttft ratio {tr} must exceed 1");
}

fn fault_matrix(faults: &[&str]) -> ScenarioMatrix {
    ScenarioMatrix {
        faults: faults.iter().map(|s| s.to_string()).collect(),
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    }
}

#[test]
fn chaos_extension_perturbs_no_calm_cells() {
    // The fault-injection contract: adding chaos presets to the grid's
    // fault axis leaves every no-fault cell — and the summary rows derived
    // from them — byte-identical. The default FaultSpec schedules zero
    // events, so the event core's sequence numbers (and therefore every
    // tie-break) are untouched.
    let calm = fault_matrix(&[NO_FAULTS]).run(2);
    let extended =
        fault_matrix(&[NO_FAULTS, "chaos-gpu-failures", "chaos-flaky-reconfig"]).run(2);
    assert_eq!(extended.cells.len(), calm.cells.len() * 3);
    // The calm cells are the byte-identical subset (fault-major cell order
    // inside each preset keeps them a prefix, but filter to be explicit).
    let calm_cells: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.fault == NO_FAULTS)
        .collect();
    assert_eq!(calm_cells.len(), calm.cells.len());
    for (a, b) in calm.cells.iter().zip(calm_cells) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "calm cell ({}, {}, {}) perturbed by the chaos extension",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // Calm summary rows are identical too.
    let calm_summary: Vec<_> = extended
        .summary()
        .into_iter()
        .filter(|r| r.fault == NO_FAULTS)
        .collect();
    assert_eq!(calm.summary(), calm_summary);
    // Fault keys exist on exactly the chaos cells.
    for c in &extended.cells {
        let j = c.to_json();
        let chaos = c.fault != NO_FAULTS;
        assert_eq!(j.opt("fault").is_some(), chaos, "fault key on {}", c.platform);
        assert_eq!(
            j.opt("availability").is_some(),
            chaos,
            "availability key on ({}, {})",
            c.platform,
            c.fault
        );
        assert_eq!(j.opt("failed").is_some(), chaos);
    }
    // The fault grid round-trips losslessly and is --jobs invariant.
    let back = MatrixReport::from_json(&extended.to_json()).unwrap();
    assert_eq!(back, extended);
    assert_eq!(
        back.to_json().to_string_pretty(),
        extended.to_json().to_string_pretty()
    );
    let again =
        fault_matrix(&[NO_FAULTS, "chaos-gpu-failures", "chaos-flaky-reconfig"]).run(1);
    assert_eq!(
        json::fingerprint(&extended.to_json()),
        json::fingerprint(&again.to_json())
    );
}

#[test]
fn chaos_gpu_failures_headline_accounting() {
    // Under the GPU-failure chaos preset every platform must feel the
    // failures: fleet availability strictly below 1, per-function MTTR
    // samples present, failed-request accounting exported — and the whole
    // grid deterministic across --jobs values.
    let mk = || ScenarioMatrix {
        faults: vec!["chaos-gpu-failures".to_string()],
        seconds: 240,
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    };
    let report = mk().run(2);
    assert_eq!(report.cells.len(), 6);
    for c in &report.cells {
        assert!(c.served > 0, "{} served nothing under chaos", c.platform);
        let avail = c.availability.unwrap_or_else(|| {
            panic!("({}, seed {}) exported no availability", c.platform, c.seed)
        });
        assert!(
            (0.0..1.0).contains(&avail),
            "({}, seed {}) availability {avail} not in [0,1)",
            c.platform,
            c.seed
        );
        assert!(c.failed.is_some(), "{} exported no failed count", c.platform);
    }
    let summary = report.summary();
    let row = |p: &str| summary.iter().find(|r| r.platform == p).unwrap();
    for p in ["has-gpu", "kserve", "fast-gshare"] {
        let r = row(p);
        assert_eq!(r.fault, "chaos-gpu-failures");
        assert!(r.availability.unwrap() < 1.0, "{p} availability");
        let mttr = r.mttr.unwrap_or_else(|| panic!("{p} has no MTTR samples"));
        assert!(mttr.is_finite() && mttr > 0.0, "{p} mttr {mttr}");
    }
    // The MTTR headline ratio materialises for the chaos rows.
    let ratios = report.ratios_vs_has_gpu();
    for p in ["kserve", "fast-gshare"] {
        let r = ratios.iter().find(|r| r.platform == p).unwrap();
        assert_eq!(r.fault, "chaos-gpu-failures");
        assert!(r.mttr_ratio.is_some(), "{p} missing mttr ratio");
    }
    // Determinism across worker counts — the CI chaos smoke's twin.
    let again = mk().run(1);
    assert_eq!(
        json::fingerprint(&report.to_json()),
        json::fingerprint(&again.to_json())
    );
}

#[test]
fn pipeline_extension_perturbs_no_stock_cells() {
    // The workflow contract: adding the pipeline presets to a grid leaves
    // every pre-existing single-function cell byte-identical — an empty
    // workflow config schedules no stage hops, consumes no RNG, and gates
    // every workflow export key off.
    let stock = registry_matrix(&["has-gpu", "kserve", "fast-gshare"]).run(2);
    let mk = || ScenarioMatrix {
        presets: vec![
            Preset::Standard,
            Preset::PipelineVision,
            Preset::PipelineMixed,
        ],
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    };
    let extended = mk().run(2);
    assert_eq!(extended.cells.len(), stock.cells.len() * 3);
    let shared: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.preset == Preset::Standard)
        .collect();
    assert_eq!(shared.len(), stock.cells.len());
    for (a, b) in stock.cells.iter().zip(shared) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "stock cell ({}, {}, {}) perturbed by the pipeline extension",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // Stock summary rows are identical too (pipeline rows only append).
    let stock_summary: Vec<_> = extended
        .summary()
        .into_iter()
        .filter(|r| r.preset == Preset::Standard)
        .collect();
    assert_eq!(stock.summary(), stock_summary);
    // Workflow keys exist on exactly the pipeline cells.
    for c in &extended.cells {
        let pipeline = matches!(c.preset, Preset::PipelineVision | Preset::PipelineMixed);
        assert_eq!(
            c.to_json().opt("workflows").is_some(),
            pipeline,
            "({}, {}, {}) workflow key presence",
            c.platform,
            c.preset.name(),
            c.seed
        );
        assert_eq!(!c.workflows.is_empty(), pipeline);
    }
    // Pipeline cells actually flowed traffic through the whole DAG: every
    // stage function served, and the workflow accounting closed every
    // opened origin exactly once (served + dropped roll up the chain).
    for c in extended.cells.iter().filter(|c| !c.workflows.is_empty()) {
        let wf = &c.workflows[0];
        assert!(
            wf.served > 0,
            "({}, {}, {}) completed no workflows",
            c.platform,
            c.preset.name(),
            c.seed
        );
        assert!((0.0..=1.0).contains(&wf.e2e_violation_rate));
        assert!(c.functions.iter().all(|f| f.name.starts_with(&format!("{}:", wf.name))));
    }
    // The extended grid round-trips losslessly and is --jobs invariant.
    let back = MatrixReport::from_json(&extended.to_json()).unwrap();
    assert_eq!(back, extended);
    assert_eq!(
        back.to_json().to_string_pretty(),
        extended.to_json().to_string_pretty()
    );
    let again = mk().run(1);
    assert_eq!(
        json::fingerprint(&extended.to_json()),
        json::fingerprint(&again.to_json())
    );
}

#[test]
fn trace_extension_perturbs_no_stock_cells() {
    // The trace-backend contract: adding the sampled-trace preset to a grid
    // leaves every stock synthetic cell byte-identical. The trace path
    // draws only from its own RNG streams (per-function + rank-shuffle,
    // disjoint from the sim/trace-gen streams) and flips its sim knobs
    // (cold start, lazy idle sweep) only inside its own cells.
    let stock = registry_matrix(&["has-gpu", "kserve", "fast-gshare"]).run(2);
    let mk = || ScenarioMatrix {
        presets: vec![Preset::Standard, Preset::TraceAzureSmall],
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    };
    let extended = mk().run(2);
    assert_eq!(extended.cells.len(), stock.cells.len() * 2);
    let shared: Vec<&CellResult> = extended
        .cells
        .iter()
        .filter(|c| c.preset == Preset::Standard)
        .collect();
    assert_eq!(shared.len(), stock.cells.len());
    for (a, b) in stock.cells.iter().zip(shared) {
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "stock cell ({}, {}, {}) perturbed by the trace extension",
            a.platform,
            a.preset.name(),
            a.seed
        );
    }
    // Stock summary rows are identical too (trace rows only append).
    let stock_summary: Vec<_> = extended
        .summary()
        .into_iter()
        .filter(|r| r.preset == Preset::Standard)
        .collect();
    assert_eq!(stock.summary(), stock_summary);
    // The trace cells ran the sampled population end-to-end: traffic
    // flowed (served or dropped — every arrival is accounted), and the
    // export carries only *touched* sampled functions, never the idle
    // bulk of the population.
    for c in extended
        .cells
        .iter()
        .filter(|c| c.preset == Preset::TraceAzureSmall)
    {
        assert!(
            c.served + c.dropped > 0,
            "({}, seed {}) trace cell saw no traffic",
            c.platform,
            c.seed
        );
        assert!(!c.functions.is_empty());
        assert!(
            c.functions.len() <= 48,
            "trace cell exported {} rows for a 48-function population",
            c.functions.len()
        );
        assert!(c.functions.iter().all(|f| f.name.starts_with("azfn-")));
        assert!(
            c.functions.iter().all(|f| f.served + f.dropped > 0),
            "({}, seed {}) exported an untouched function row",
            c.platform,
            c.seed
        );
    }
    // The fine-grained paper platform actually serves under the sampled
    // population (whole-GPU baselines may starve most of it — that is the
    // comparison the preset exists to make).
    let has = extended
        .cells
        .iter()
        .find(|c| c.preset == Preset::TraceAzureSmall && c.platform == "has-gpu")
        .unwrap();
    assert!(has.served > 0, "has-gpu served nothing on the sampled trace");
    // The extended grid round-trips losslessly and is --jobs invariant
    // (sampling order and metric sharding must not leak into the export).
    let back = MatrixReport::from_json(&extended.to_json()).unwrap();
    assert_eq!(back, extended);
    let again = mk().run(1);
    assert_eq!(
        json::fingerprint(&extended.to_json()),
        json::fingerprint(&again.to_json())
    );
}

#[test]
fn pipeline_mixed_headline_directions() {
    // The paper-shaped outcome for the branching-DAG grid: HAS-GPU's
    // co-scaled stages keep the e2e tail inside the budget at fine-grained
    // cost, so its tail-per-dollar product (e2e P99 × chain $/1k) beats
    // both baselines — kserve burns whole GPUs per stage, fast-gshare lets
    // the bottleneck stage starve the chain's tail.
    let report = ScenarioMatrix {
        presets: vec![Preset::PipelineMixed],
        seconds: 240,
        ..registry_matrix(&["has-gpu", "kserve", "fast-gshare"])
    }
    .run(2);
    let summary = report.summary();
    let row = |p: &str| summary.iter().find(|r| r.platform == p).unwrap();
    let has = row("has-gpu");
    for p in ["has-gpu", "kserve", "fast-gshare"] {
        let r = row(p);
        let e2e = r.e2e_p99.unwrap_or_else(|| panic!("{p} has no e2e_p99"));
        let dollars = r.e2e_cost_per_1k.unwrap_or_else(|| panic!("{p} has no wf $/1k"));
        assert!(e2e > 0.0 && e2e.is_finite(), "{p} e2e_p99 {e2e}");
        assert!(dollars > 0.0, "{p} wf $/1k {dollars}");
    }
    for p in ["kserve", "fast-gshare"] {
        let b = row(p);
        let has_product = has.e2e_p99.unwrap() * has.e2e_cost_per_1k.unwrap();
        let b_product = b.e2e_p99.unwrap() * b.e2e_cost_per_1k.unwrap();
        assert!(
            has_product < b_product,
            "has-gpu e2e×$ {has_product} must beat {p} {b_product}"
        );
    }
    // And the e2e headline ratio materialises for the pipeline rows.
    let ratios = report.ratios_vs_has_gpu();
    for p in ["kserve", "fast-gshare"] {
        let r = ratios.iter().find(|r| r.platform == p).unwrap();
        assert!(r.e2e_ratio.is_some(), "{p} missing e2e ratio");
    }
}

#[test]
fn uniform_fleet_export_is_byte_identical_to_the_pre_fleet_path() {
    // Belt-and-braces for the fleet axis specifically: the frozen pre-fleet
    // construction (homogeneous ClusterState::new path, no fleet axis)
    // versus the registry path running the explicit `uniform-v100` fleet
    // through FleetSpec::classes_for + ClusterState::from_classes. Full
    // export, byte for byte.
    let golden = frozen_run(&[Preset::Standard]).to_json().to_string_pretty();
    let via_fleet = fleet_matrix(&[DEFAULT_FLEET]).run(3).to_json().to_string_pretty();
    assert_eq!(
        golden, via_fleet,
        "uniform-v100 BENCH_sim.json must not change under the GpuClass catalog"
    );
}
