//! Billing-parity property test (ISSUE acceptance, CI-run via `cargo test`):
//! for any random scaling-action sequence applied through the
//! Re-configurator, the [`BillingLedger`] total equals the analytic
//! slice-time integral in **both** billing modes —
//!
//! * fine-grained: Σ over held intervals of `sm × quota × dur`;
//! * whole-GPU:    Σ over held intervals of `1 × 1 × dur`
//!   (the analytic whole-GPU cost a KServe run would pay);
//!
//! and `bill_whole_gpu` is respected at resize/remove boundaries (the seed's
//! `apply_action` path hard-coded fine-grained there).

use has_gpu::cluster::{
    Applied, ClusterState, FunctionSpec, GpuId, PodId, Reconfigurator, ScalingAction,
};
use has_gpu::metrics::{BillingLedger, BillingMode};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::prop_assert;
use has_gpu::util::proptest::{run_prop, PropConfig};
use has_gpu::vgpu::{quota_to_f64, sm_to_f64, QUOTA_STEP, SM_STEP};

/// $/h chosen so that 1 slice-second == $1: ledger costs read directly as
/// the analytic integral.
const PRICE: f64 = 3600.0;

#[test]
fn ledger_total_matches_analytic_slice_time_integral() {
    run_prop(
        "billing-parity",
        PropConfig {
            cases: 96,
            max_size: 48,
            ..PropConfig::default()
        },
        |rng, size| {
            let spec = FunctionSpec {
                name: "mobilenetv2".into(),
                graph: zoo_graph(ZooModel::MobileNetV2),
                slo: 0.1,
                batch: 1,
                artifact: None,
            };
            let perf = PerfModel::default();
            let mut cluster = ClusterState::new(2, perf.dev.mem_cap);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 7);
            let mut fine = BillingLedger::new(BillingMode::FineGrained, PRICE);
            let mut whole = BillingLedger::new(BillingMode::WholeGpu, PRICE);

            // Live pods and the independent analytic accumulators.
            let mut live: Vec<(PodId, u32, u32)> = Vec::new(); // (pod, sm‰, q‰)
            let mut fine_ref = 0.0f64;
            let mut whole_ref = 0.0f64;
            let mut now = 0.0f64;

            for step in 0..size {
                // Advance virtual time; every live pod accrues slice-time.
                let dt = rng.next_f64() * 3.0;
                for &(_, sm, q) in &live {
                    fine_ref += sm_to_f64(sm) * quota_to_f64(q) * dt;
                    whole_ref += dt;
                }
                now += dt;

                // One random scaling action; Err (alignment/capacity races)
                // must leave both ledgers untouched.
                let action = match rng.next_below(3) {
                    0 => ScalingAction::CreatePod {
                        function: spec.name.clone(),
                        gpu: GpuId(rng.next_below(2) as usize),
                        sm: SM_STEP * (1 + rng.next_below(8) as u32),
                        quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
                        batch: spec.batch,
                        new_gpu: false,
                    },
                    1 if !live.is_empty() => {
                        let (pod, _, _) = live[rng.next_below(live.len() as u64) as usize];
                        ScalingAction::SetQuota {
                            pod,
                            quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
                        }
                    }
                    _ if !live.is_empty() => {
                        let (pod, _, _) = live[rng.next_below(live.len() as u64) as usize];
                        ScalingAction::RemovePod { pod }
                    }
                    _ => continue,
                };
                match recon.apply(&mut cluster, &perf, &action, now) {
                    Ok(Applied::PodCreated { pod, .. }) => {
                        let p = cluster.pod(pod).expect("created");
                        fine.open(pod, &p.function, p.sm, p.quota, now);
                        whole.open(pod, &p.function, p.sm, p.quota, now);
                        live.push((pod, p.sm, p.quota));
                    }
                    Ok(Applied::QuotaSet { pod, new, .. }) => {
                        fine.resize(pod, new, now);
                        whole.resize(pod, new, now);
                        let entry = live.iter_mut().find(|(id, _, _)| *id == pod).unwrap();
                        entry.2 = new;
                    }
                    Ok(Applied::PodRemoved { pod }) => {
                        fine.close(pod, now);
                        whole.close(pod, now);
                        live.retain(|(id, _, _)| *id != pod);
                    }
                    Err(_) => {}
                }
                prop_assert!(
                    fine.open_accounts() == live.len(),
                    "step {step}: ledger tracks {} accounts, {} pods live",
                    fine.open_accounts(),
                    live.len()
                );
            }

            // Final settlement, then compare against the analytic integrals.
            let t_end = now + rng.next_f64() * 2.0;
            for &(_, sm, q) in &live {
                fine_ref += sm_to_f64(sm) * quota_to_f64(q) * (t_end - now);
                whole_ref += t_end - now;
            }
            let fine_total = fine.into_meter(t_end).total_cost();
            let whole_total = whole.into_meter(t_end).total_cost();
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * (1.0 + a.abs().max(b.abs()));
            prop_assert!(
                close(fine_total, fine_ref),
                "fine-grained: ledger {fine_total} vs analytic {fine_ref}"
            );
            prop_assert!(
                close(whole_total, whole_ref),
                "whole-GPU: ledger {whole_total} vs analytic {whole_ref}"
            );
            Ok(())
        },
    );
}

#[test]
fn fault_truncation_closes_accounts_at_failure_instants() {
    // Fault-injection extension of the parity property: random GPU
    // failures interleave with the scaling actions, and every account of a
    // pod resident on the dying device closes **at the failure instant** —
    // the analytic integral simply stops accruing those pods there. If the
    // ledger billed a single pod-second past a device death, in either
    // mode, the totals diverge.
    const N_GPUS: usize = 3;
    run_prop(
        "billing-fault-truncation",
        PropConfig {
            cases: 96,
            max_size: 48,
            ..PropConfig::default()
        },
        |rng, size| {
            let spec = FunctionSpec {
                name: "mobilenetv2".into(),
                graph: zoo_graph(ZooModel::MobileNetV2),
                slo: 0.1,
                batch: 1,
                artifact: None,
            };
            let perf = PerfModel::default();
            let mut cluster = ClusterState::new(N_GPUS, perf.dev.mem_cap);
            cluster.register_function(spec.clone());
            let mut recon = Reconfigurator::new(&cluster, 7);
            let mut fine = BillingLedger::new(BillingMode::FineGrained, PRICE);
            let mut whole = BillingLedger::new(BillingMode::WholeGpu, PRICE);

            // (pod, sm‰, q‰, host gpu) plus the independent accumulators.
            let mut live: Vec<(PodId, u32, u32, GpuId)> = Vec::new();
            let mut down = [false; N_GPUS];
            let mut fine_ref = 0.0f64;
            let mut whole_ref = 0.0f64;
            let mut now = 0.0f64;

            for step in 0..size {
                let dt = rng.next_f64() * 3.0;
                for &(_, sm, q, _) in &live {
                    fine_ref += sm_to_f64(sm) * quota_to_f64(q) * dt;
                    whole_ref += dt;
                }
                now += dt;

                match rng.next_below(5) {
                    // The planner contract: placement only ever targets
                    // GPUs that are up, so the generator does too.
                    0 | 1 => {
                        let up: Vec<usize> =
                            (0..N_GPUS).filter(|&g| !down[g]).collect();
                        if up.is_empty() {
                            continue;
                        }
                        let gpu = GpuId(up[rng.next_below(up.len() as u64) as usize]);
                        let action = ScalingAction::CreatePod {
                            function: spec.name.clone(),
                            gpu,
                            sm: SM_STEP * (1 + rng.next_below(8) as u32),
                            quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
                            batch: spec.batch,
                            new_gpu: false,
                        };
                        if let Ok(Applied::PodCreated { pod, .. }) =
                            recon.apply(&mut cluster, &perf, &action, now)
                        {
                            let p = cluster.pod(pod).expect("created");
                            fine.open(pod, &p.function, p.sm, p.quota, now);
                            whole.open(pod, &p.function, p.sm, p.quota, now);
                            live.push((pod, p.sm, p.quota, p.gpu));
                        }
                    }
                    2 if !live.is_empty() => {
                        let (pod, _, _, _) =
                            live[rng.next_below(live.len() as u64) as usize];
                        let action = ScalingAction::SetQuota {
                            pod,
                            quota: QUOTA_STEP * (1 + rng.next_below(10) as u32),
                        };
                        if let Ok(Applied::QuotaSet { pod, new, .. }) =
                            recon.apply(&mut cluster, &perf, &action, now)
                        {
                            fine.resize(pod, new, now);
                            whole.resize(pod, new, now);
                            let e =
                                live.iter_mut().find(|(id, _, _, _)| *id == pod).unwrap();
                            e.2 = new;
                        }
                    }
                    3 if !live.is_empty() => {
                        let (pod, _, _, _) =
                            live[rng.next_below(live.len() as u64) as usize];
                        if let Ok(Applied::PodRemoved { pod }) = recon.apply(
                            &mut cluster,
                            &perf,
                            &ScalingAction::RemovePod { pod },
                            now,
                        ) {
                            fine.close(pod, now);
                            whole.close(pod, now);
                            live.retain(|(id, _, _, _)| *id != pod);
                        }
                    }
                    _ => {
                        // Flip one GPU: repair if down, otherwise fail it
                        // and truncate every resident account at `now` —
                        // exactly what run_sim's GpuFailed arm does.
                        let g = rng.next_below(N_GPUS as u64) as usize;
                        if down[g] {
                            down[g] = false;
                            cluster.set_gpu_down(GpuId(g), false);
                        } else {
                            down[g] = true;
                            cluster.set_gpu_down(GpuId(g), true);
                            live.retain(|&(pod, _, _, pg)| {
                                if pg == GpuId(g) {
                                    fine.close(pod, now);
                                    whole.close(pod, now);
                                    let evicted = recon.evict_pod(&mut cluster, pod);
                                    debug_assert!(evicted.is_some());
                                    false
                                } else {
                                    true
                                }
                            });
                        }
                    }
                }
                prop_assert!(
                    fine.open_accounts() == live.len()
                        && whole.open_accounts() == live.len(),
                    "step {step}: ledgers track {}/{} accounts, {} pods live",
                    fine.open_accounts(),
                    whole.open_accounts(),
                    live.len()
                );
            }

            let t_end = now + rng.next_f64() * 2.0;
            for &(_, sm, q, _) in &live {
                fine_ref += sm_to_f64(sm) * quota_to_f64(q) * (t_end - now);
                whole_ref += t_end - now;
            }
            let fine_total = fine.into_meter(t_end).total_cost();
            let whole_total = whole.into_meter(t_end).total_cost();
            let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * (1.0 + a.abs().max(b.abs()));
            prop_assert!(
                close(fine_total, fine_ref),
                "fine-grained under faults: ledger {fine_total} vs analytic {fine_ref}"
            );
            prop_assert!(
                close(whole_total, whole_ref),
                "whole-GPU under faults: ledger {whole_total} vs analytic {whole_ref}"
            );
            Ok(())
        },
    );
}

#[test]
fn whole_gpu_mode_bills_full_device_through_resize_boundaries() {
    // Direct pin of the seed bug: a whole-GPU run whose pod is resized
    // mid-run must bill 1×1 for every second, not the fine-grained slice
    // before the boundary.
    let spec = FunctionSpec {
        name: "mobilenetv2".into(),
        graph: zoo_graph(ZooModel::MobileNetV2),
        slo: 0.1,
        batch: 1,
        artifact: None,
    };
    let perf = PerfModel::default();
    let mut cluster = ClusterState::new(1, perf.dev.mem_cap);
    cluster.register_function(spec.clone());
    let mut recon = Reconfigurator::new(&cluster, 3);
    let mut ledger = BillingLedger::new(BillingMode::WholeGpu, PRICE);

    let Applied::PodCreated { pod, .. } = recon
        .apply(
            &mut cluster,
            &perf,
            &ScalingAction::CreatePod {
                function: spec.name.clone(),
                gpu: GpuId(0),
                sm: 250,
                quota: 200,
                batch: 1,
                new_gpu: true,
            },
            0.0,
        )
        .unwrap()
    else {
        panic!("create failed")
    };
    ledger.open(pod, &spec.name, 250, 200, 0.0);
    recon
        .apply(&mut cluster, &perf, &ScalingAction::SetQuota { pod, quota: 800 }, 10.0)
        .unwrap();
    ledger.resize(pod, 800, 10.0);
    recon
        .apply(&mut cluster, &perf, &ScalingAction::RemovePod { pod }, 25.0)
        .unwrap();
    ledger.close(pod, 25.0);
    let total = ledger.meter().total_cost();
    assert!(
        (total - 25.0).abs() < 1e-9,
        "whole-GPU must bill 25 GPU-seconds, got {total}"
    );
}
