//! Property tests for the workflow SLO budget splitter (hand-rolled with
//! the repo's seeded PRNG — no external proptest dependency).
//!
//! For randomly generated forward-edge DAGs the splitter must:
//!
//! 1. **conserve the SLO** — along every root-to-leaf path,
//!    `Σ stage budgets + Σ hop latencies ≤ e2e SLO` whenever the SLO can
//!    cover the hop reserve at all (and never exceed the hop reserve
//!    itself otherwise);
//! 2. **never produce negative or NaN budgets**, even under degenerate
//!    latency predictions (NaN, ±∞, negatives) or repeated
//!    renormalization with rescaled predictions.

use has_gpu::model::zoo::ZooModel;
use has_gpu::util::prng::Pcg64;
use has_gpu::workflow::{split_budget, Workflow, WorkflowEdge, WorkflowStage};

/// Build a random valid workflow DAG: stage `s > 0` always receives one
/// edge from a random earlier stage (single entry, all stages reachable),
/// plus a few extra random forward edges.
fn random_dag(rng: &mut Pcg64) -> Workflow {
    let n = 1 + rng.next_below(8) as usize;
    let stages = (0..n)
        .map(|i| WorkflowStage {
            name: format!("s{i}"),
            model: ZooModel::MobileNetV2,
            batch: 1 + rng.next_below(16) as u32,
        })
        .collect();
    let mut edges = Vec::new();
    for to in 1..n {
        let from = rng.next_below(to as u64) as usize;
        edges.push(WorkflowEdge {
            from,
            to,
            payload_bytes: rng.uniform(0.0, 2e6),
        });
    }
    for _ in 0..rng.next_below(4) {
        if n < 2 {
            break;
        }
        let from = rng.next_below((n - 1) as u64) as usize;
        let to = from + 1 + rng.next_below((n - from - 1) as u64) as usize;
        edges.push(WorkflowEdge {
            from,
            to,
            payload_bytes: rng.uniform(0.0, 2e6),
        });
    }
    Workflow {
        name: "prop".into(),
        about: "random property-test DAG".into(),
        stages,
        edges,
        e2e_slo: rng.uniform(0.0, 2.0),
    }
}

/// Random per-stage latency predictions, occasionally poisoned with the
/// degenerate values a broken predictor could emit.
fn random_lats(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|_| match rng.next_below(10) {
            0 => f64::NAN,
            1 => -rng.uniform(0.0, 1.0),
            2 => f64::INFINITY,
            3 => 0.0,
            _ => rng.uniform(1e-4, 0.5),
        })
        .collect()
}

/// Longest root-to-leaf sum of `budget[s]` plus traversed hop latencies —
/// an independent DP (ascending stage index is a topological order for
/// forward edges), so the test does not reuse the library's path walker.
fn worst_path(wf: &Workflow, budgets: &[f64]) -> f64 {
    let n = wf.stages.len();
    let mut dp: Vec<f64> = (0..n).map(|s| budgets[s]).collect();
    for s in 0..n {
        for e in wf.edges.iter().filter(|e| e.to == s) {
            let via = dp[e.from] + e.hop_latency() + budgets[s];
            if via > dp[s] {
                dp[s] = via;
            }
        }
    }
    dp.iter().fold(0.0f64, |a, &b| a.max(b))
}

#[test]
fn random_dags_are_structurally_valid() {
    let mut rng = Pcg64::seeded(0xDA6);
    for _ in 0..500 {
        let wf = random_dag(&mut rng);
        wf.validate().unwrap();
        assert_eq!(wf.entry(), 0, "stage 0 is always the single entry");
    }
}

#[test]
fn budget_split_conserves_the_slo_on_every_path() {
    let mut rng = Pcg64::seeded(0x510);
    for case in 0..500 {
        let wf = random_dag(&mut rng);
        let lats = random_lats(&mut rng, wf.stages.len());
        let budgets = wf.stage_budgets(&lats);
        assert_eq!(budgets.len(), wf.stages.len());
        let h = wf.critical_path_hops();
        let worst = worst_path(&wf, &budgets);
        // The hop reserve comes off the top, so every path fits the SLO
        // whenever the SLO covers the hops; with an infeasible SLO the
        // budgets collapse to zero and only the hops remain.
        let cap = wf.e2e_slo.max(h);
        assert!(
            worst <= cap + 1e-9,
            "case {case}: path spend {worst} > cap {cap} (slo {}, hops {h})",
            wf.e2e_slo
        );
    }
}

#[test]
fn budgets_are_never_negative_or_nan_under_renormalization() {
    let mut rng = Pcg64::seeded(0xF1);
    for case in 0..500 {
        let wf = random_dag(&mut rng);
        let mut lats = random_lats(&mut rng, wf.stages.len());
        for round in 0..3 {
            let budgets = wf.stage_budgets(&lats);
            for (s, b) in budgets.iter().enumerate() {
                assert!(
                    b.is_finite() && *b >= 0.0,
                    "case {case} round {round} stage {s}: budget {b} from lats {lats:?}"
                );
            }
            // Renormalize: stages scale, predictions shift by a random
            // positive factor (sometimes degenerate again).
            for l in lats.iter_mut() {
                *l = if rng.next_below(12) == 0 {
                    f64::NAN
                } else {
                    l.abs().max(1e-6) * rng.uniform(0.25, 4.0)
                };
            }
        }
    }
}

#[test]
fn split_budget_handles_empty_and_mismatched_inputs() {
    assert!(split_budget(1.0, &[], 0, &[]).is_empty());
    // More declared stages than latencies: truncated, never a panic.
    let edges = [WorkflowEdge { from: 0, to: 1, payload_bytes: 1e5 }];
    let b = split_budget(1.0, &[0.1], 5, &edges);
    assert_eq!(b.len(), 1);
    assert!(b[0].is_finite() && b[0] >= 0.0);
}
