//! Cross-language parity: the Python build pipeline and the Rust runtime
//! must agree on (1) the perf-model surface, (2) the RaPP feature layout,
//! (3) the trained predictor's output — native Rust forward vs. the
//! python reference vs. the AOT-compiled HLO executed through PJRT.
//!
//! Requires `make artifacts`. Tests skip (with a notice) if absent so plain
//! `cargo test` stays green in a fresh checkout.

use has_gpu::model::OpGraph;
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::{extract, FeatureMode};
use has_gpu::rapp::{LatencyPredictor, RappPredictor};
use has_gpu::runtime::{PjrtRapp, PjrtRuntime};
use has_gpu::util::json;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("golden/perf_golden.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Per-file skip guard: each test names exactly the artifacts it consumes so
/// a partially-built artifacts/ directory skips with a message instead of
/// panicking on a missing file.
fn require(dir: &std::path::Path, rel: &str) -> Option<PathBuf> {
    let p = dir.join(rel);
    if p.exists() {
        Some(p)
    } else {
        eprintln!("SKIP: missing artifact {} (run `make artifacts`)", p.display());
        None
    }
}

/// PJRT-execution tests additionally need the `pjrt` feature.
fn pjrt_enabled() -> bool {
    if cfg!(feature = "pjrt") {
        true
    } else {
        eprintln!("SKIP: PJRT execution needs the `pjrt` feature (--features pjrt)");
        false
    }
}

fn load_golden(dir: &std::path::Path) -> (json::Json, OpGraph) {
    let doc = json::parse_file(&dir.join("golden/perf_golden.json")).unwrap();
    let graph = OpGraph::from_json(doc.get("graph").unwrap()).unwrap();
    (doc, graph)
}

#[test]
fn perf_model_matches_python_to_1e9() {
    let Some(dir) = artifacts_dir() else { return };
    let (doc, graph) = load_golden(&dir);
    let pm = PerfModel::default();
    for cfg in doc.get("configs").unwrap().as_arr().unwrap() {
        let batch = cfg.get("batch").unwrap().as_usize().unwrap() as u32;
        let sm = cfg.get("sm").unwrap().as_f64().unwrap();
        let quota = cfg.get("quota").unwrap().as_f64().unwrap();
        let want_lat = cfg.get("latency").unwrap().as_f64().unwrap();
        let want_raw = cfg.get("raw_time").unwrap().as_f64().unwrap();
        let want_cap = cfg.get("capacity").unwrap().as_f64().unwrap();
        let lat = pm.latency(&graph, batch, sm, quota);
        let raw = pm.raw_graph_time(&graph, batch, sm);
        let cap = pm.capacity(&graph, batch, sm, quota);
        assert!(
            (lat - want_lat).abs() / want_lat < 1e-9,
            "latency b{batch} sm{sm} q{quota}: rust {lat} vs python {want_lat}"
        );
        assert!((raw - want_raw).abs() / want_raw < 1e-9);
        assert!((cap - want_cap).abs() / want_cap < 1e-9);
    }
}

#[test]
fn op_times_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let (doc, graph) = load_golden(&dir);
    let pm = PerfModel::default();
    let batch = doc.get("profile_batch").unwrap().as_usize().unwrap() as u32;
    let rows = doc.get("op_times").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), graph.nodes.len());
    for (node, row) in graph.nodes.iter().zip(rows) {
        let want = row.as_f64_vec().unwrap();
        for (&sm, &w) in PerfModel::PROFILE_SMS.iter().zip(&want) {
            let got = pm.op_time(node, batch, sm);
            assert!((got - w).abs() / w < 1e-9, "op_time {got} vs {w}");
        }
    }
}

#[test]
fn features_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let (doc, graph) = load_golden(&dir);
    let pm = PerfModel::default();
    let cfg = doc.get("features_config").unwrap();
    let batch = cfg.get("batch").unwrap().as_usize().unwrap() as u32;
    let sm = cfg.get("sm").unwrap().as_f64().unwrap();
    let quota = cfg.get("quota").unwrap().as_f64().unwrap();
    let feats = extract(&graph, batch, sm, quota, &pm, FeatureMode::Full);
    let want_op = doc.get("op_features").unwrap().as_arr().unwrap();
    assert_eq!(want_op.len(), feats.op_feats.len());
    for (row, want_row) in feats.op_feats.iter().zip(want_op) {
        let want = want_row.as_f64_vec().unwrap();
        assert_eq!(row.len(), want.len());
        for (i, (&g, &w)) in row.iter().zip(&want).enumerate() {
            assert!(
                (g as f64 - w).abs() < 1e-5 + w.abs() * 1e-5,
                "op feature col {i}: rust {g} vs python {w}"
            );
        }
    }
    let want_g = doc.get("graph_features").unwrap().as_f64_vec().unwrap();
    assert_eq!(feats.graph_feats.len(), want_g.len());
    for (i, (&g, &w)) in feats.graph_feats.iter().zip(&want_g).enumerate() {
        assert!(
            (g as f64 - w).abs() < 1e-5 + w.abs() * 1e-5,
            "graph feature col {i}: rust {g} vs python {w}"
        );
    }
}

#[test]
fn native_forward_matches_python_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(weights) = require(&dir, "rapp_weights.json") else { return };
    let (doc, graph) = load_golden(&dir);
    let preds = doc.get("rapp_preds").unwrap().as_arr().unwrap();
    assert!(!preds.is_empty());
    let rapp = RappPredictor::load(&weights, PerfModel::default()).unwrap();
    for p in preds {
        let batch = p.get("batch").unwrap().as_usize().unwrap() as u32;
        let sm = p.get("sm").unwrap().as_f64().unwrap();
        let quota = p.get("quota").unwrap().as_f64().unwrap();
        let want = p.get("ln_latency_ms").unwrap().as_f64().unwrap();
        let got = rapp.forward(&graph, batch, sm, quota) as f64;
        assert!(
            (got - want).abs() < 1e-3,
            "native fwd {got} vs python {want}"
        );
    }
}

#[test]
fn pjrt_hlo_forward_matches_native() {
    if !pjrt_enabled() {
        return;
    }
    let Some(dir) = artifacts_dir() else { return };
    let Some(weights) = require(&dir, "rapp_weights.json") else { return };
    let Some(hlo) = require(&dir, "rapp.hlo.txt") else { return };
    let (_doc, graph) = load_golden(&dir);
    let pm = PerfModel::default();
    let rapp = RappPredictor::load(&weights, pm.clone()).unwrap();
    let runtime = Arc::new(PjrtRuntime::new().unwrap());
    let f_op = rapp.weights.mode.f_op();
    let f_g = rapp.weights.mode.f_g();
    let pjrt = PjrtRapp::new(runtime, hlo, f_op, f_g);
    for &(batch, sm, quota) in &[(1u32, 1.0f64, 1.0f64), (4, 0.5, 0.6), (16, 0.2, 0.3)] {
        let feats = extract(&graph, batch, sm, quota, &pm, FeatureMode::Full);
        let hlo = pjrt.forward(&feats).unwrap() as f64;
        let native = rapp.forward(&graph, batch, sm, quota) as f64;
        assert!(
            (hlo - native).abs() < 1e-3,
            "b{batch} sm{sm} q{quota}: HLO {hlo} vs native {native}"
        );
    }
}

#[test]
fn trained_rapp_accurate_on_unseen_zoo_models() {
    // The Rust zoo graphs were never in the training corpus — this is the
    // paper's "unseen models" test (Fig. 5 right) executed end-to-end in Rust.
    let Some(dir) = artifacts_dir() else { return };
    let Some(weights) = require(&dir, "rapp_weights.json") else { return };
    let pm = PerfModel::default();
    let rapp = RappPredictor::load(&weights, pm.clone()).unwrap();
    let mut errs = Vec::new();
    for m in has_gpu::model::zoo::ALL_ZOO {
        let g = has_gpu::model::zoo::zoo_graph(m);
        for &(batch, sm, quota) in &[(1u32, 0.3f64, 0.5f64), (8, 0.6, 0.8), (16, 0.15, 0.25)] {
            let truth = pm.latency(&g, batch, sm, quota);
            let pred = rapp.latency(has_gpu::rapp::PredictQuery::new(&g, batch, sm, quota));
            errs.push((truth - pred).abs() / truth);
        }
    }
    let mape = 100.0 * errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mape < 15.0, "zoo-model MAPE {mape:.2}%");
}

#[test]
fn servable_artifacts_execute() {
    if !pjrt_enabled() {
        return;
    }
    let Some(dir) = artifacts_dir() else { return };
    if require(&dir, "manifest.json").is_none() {
        return;
    }
    let manifest = has_gpu::runtime::Manifest::load(&dir).unwrap();
    assert!(!manifest.models.is_empty());
    let rt = PjrtRuntime::new().unwrap();
    for art in manifest.models.iter().filter(|m| m.batch <= 4) {
        let input = vec![0.1f32; art.batch * art.input_dim];
        let out = rt
            .infer(
                &art.path,
                &[(&input, &[art.batch as i64, art.input_dim as i64])],
            )
            .unwrap();
        assert_eq!(out.values.len(), art.batch * art.output_dim, "{}", art.name);
        assert!(out.values.iter().all(|v| v.is_finite()), "{}", art.name);
    }
}
