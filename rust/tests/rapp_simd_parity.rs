//! SIMD lane-parallel forward vs. the scalar reference: randomized
//! property pinning of the bit-identity contract. The lane kernel
//! (`Dense::forward_rows_lanes`) claims per-row bit-identity *by
//! construction* — per-(row, output) accumulation order equals the scalar
//! loop and the per-lane zero-skip select leaves accumulator bits untouched
//! exactly where the scalar `continue` does. This suite hammers that claim
//! over random graphs, batches, SM fractions, quotas, and class factors,
//! including lattice sizes that are not a multiple of the lane width (the
//! scalar-tail path). No external property-testing crate: seeded `Pcg64`
//! loops keep failures reproducible by trial index.

use has_gpu::model::{GraphBuilder, OpGraph, OpKind};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::FeatureMode;
use has_gpu::rapp::nn::LANES;
use has_gpu::rapp::{RappPredictor, RappWeights};
use has_gpu::util::prng::Pcg64;

/// A random linear-ish op graph (2–10 nodes) drawn from the builder's op
/// vocabulary. Shapes are kept small — the property is about f32 operation
/// order, not realism — but cover every kernel-count and zero-feature case
/// (elementwise ops produce zero `params`, pooling zero FLOP-heavy columns).
fn random_graph(rng: &mut Pcg64, tag: usize) -> OpGraph {
    let mut b = GraphBuilder::new(&format!("rand-simd-{tag}"), "proptest");
    let mut last = b.conv(
        &[],
        1 + 2 * rng.next_below(2) as u32,
        3,
        8 + rng.next_below(24) as u32,
        8 + rng.next_below(24) as u32,
        1 + rng.next_below(2) as u32,
        1 + rng.next_below(3) as u32,
    );
    for _ in 0..1 + rng.next_below(8) {
        last = match rng.next_below(5) {
            0 => b.conv(
                &[last],
                3,
                8,
                8 + rng.next_below(16) as u32,
                7 + rng.next_below(8) as u32,
                1,
                1 + rng.next_below(4) as u32,
            ),
            1 => b.dense(
                &[last],
                32 + rng.next_below(96) as u32,
                16 + rng.next_below(48) as u32,
            ),
            2 => b.elemwise(&[last], OpKind::Relu, 1e4 + rng.uniform(0.0, 1e5), 0.0),
            3 => b.pool(&[last], 8 + rng.next_below(24) as u32, 7, 2),
            _ => b.attention(&[last], 16 + rng.next_below(48) as u32, 32),
        };
    }
    b.build()
}

#[test]
fn lane_parallel_batched_forward_is_bit_identical_to_scalar_for_random_graphs() {
    let pm = PerfModel::default();
    let mut rng = Pcg64::seeded(0x51bd);
    let batches = [1u32, 2, 4, 8, 16, 32];
    let factors = [1.0, 0.4, 0.7, 2.0];
    for trial in 0..30usize {
        let g = random_graph(&mut rng, trial);
        let hidden = 16 * (1 + rng.next_below(3) as usize);
        let mode = if trial % 4 == 3 { FeatureMode::StaticOnly } else { FeatureMode::Full };
        let rapp = RappPredictor::new(RappWeights::random(mode, hidden, trial as u64), pm.clone());
        let batch = batches[rng.next_below(batches.len() as u64) as usize];
        let sm = (1 + rng.next_below(20)) as f64 / 20.0;
        let factor = factors[rng.next_below(factors.len() as u64) as usize];
        // Random lattice length in [1, 2·LANES+3): full lane blocks, scalar
        // tails, and all-tail (rows < LANES) passes all occur.
        let rows = 1 + rng.next_below(2 * LANES as u64 + 2) as usize;
        let quotas: Vec<f64> = (0..rows)
            .map(|_| (1 + rng.next_below(1000)) as f64 / 1000.0)
            .collect();

        let mut simd = Vec::new();
        let mut scalar = Vec::new();
        rapp.forward_batch_at(&g, batch, sm, &quotas, factor, &mut simd);
        rapp.forward_batch_scalar_ref(&g, batch, sm, &quotas, factor, &mut scalar);
        assert_eq!(simd.len(), rows);
        assert_eq!(scalar.len(), rows);
        for (row, (&a, &b)) in simd.iter().zip(&scalar).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "trial {trial} row {row}/{rows} (batch {batch} sm {sm} factor {factor}): \
                 lane kernel diverged from scalar reference"
            );
        }
        // Every batched row must also equal the one-at-a-time scalar entry
        // point — the surface the plan-parity golden gates are pinned on.
        for (row, &q) in quotas.iter().enumerate() {
            let one = rapp.forward_at(&g, batch, sm, q, factor);
            assert_eq!(
                one.to_bits(),
                simd[row].to_bits(),
                "trial {trial} row {row}: batched row vs scalar forward_at"
            );
        }
    }
}

#[test]
fn tail_lengths_around_the_lane_width_all_agree() {
    // Deterministic sweep of the block/tail boundary: every length from 1 to
    // 3·LANES+1 — each splits differently into lane blocks + scalar tail.
    let pm = PerfModel::default();
    let rapp = RappPredictor::new(RappWeights::random(FeatureMode::Full, 32, 97), pm);
    let mut rng = Pcg64::seeded(0x7a11);
    let g = random_graph(&mut rng, 999);
    let mut simd = Vec::new();
    let mut scalar = Vec::new();
    for rows in 1..=3 * LANES + 1 {
        let quotas: Vec<f64> = (0..rows).map(|i| (i % 1000 + 1) as f64 / 1000.0).collect();
        rapp.forward_batch_at(&g, 8, 0.5, &quotas, 1.0, &mut simd);
        rapp.forward_batch_scalar_ref(&g, 8, 0.5, &quotas, 1.0, &mut scalar);
        for (row, (&a, &b)) in simd.iter().zip(&scalar).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} row={row}");
        }
    }
}
