//! Cluster-scale simulation integration tests: the paper's qualitative
//! results (Figs. 6 and 7) must hold on small-but-real runs of the full
//! pipeline (workload → gateway queues → scaling policies → vGPU accounting
//! → metrics/cost). The benches regenerate the full figures; these tests pin
//! the *orderings* so regressions fail fast.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::baselines::{FastGSharePolicy, KServePolicy};
use has_gpu::cluster::FunctionSpec;
use has_gpu::metrics::{BillingMode, RunReport};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::sim::{run_sim, SimConfig};
use has_gpu::workload::{Preset, Trace, TraceGen};

fn functions() -> Vec<FunctionSpec> {
    let perf = PerfModel::default();
    [
        ZooModel::ResNet50,
        ZooModel::MobileNetV2,
        ZooModel::BertTiny,
        ZooModel::ConvNextTiny,
        ZooModel::Vgg16,
        ZooModel::DlrmSmall,
    ]
    .iter()
        .map(|&m| {
            let graph = zoo_graph(m);
            let baseline = perf.latency(&graph, 1, 1.0, 1.0);
            let slo = baseline * 3.0;
            // Serving batch: the largest that still leaves half the SLO as
            // queueing/scaling headroom on a full GPU.
            let batch = [16u32, 8, 4, 2, 1]
                .into_iter()
                .find(|&b| perf.latency(&graph, b, 1.0, 1.0) <= slo * 0.5)
                .unwrap_or(1);
            FunctionSpec {
                name: graph.name.clone(),
                slo,
                batch,
                graph,
                artifact: None,
            }
        })
        .collect()
}

fn trace(fns: &[FunctionSpec], preset: Preset) -> Trace {
    let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    TraceGen::preset(preset, 11, 240, 150.0).generate(&names)
}

fn run(policy: &mut dyn ScalingPolicy, preset: Preset, whole_gpu: bool) -> RunReport {
    let fns = functions();
    let tr = trace(&fns, preset);
    run_sim(
        policy,
        &fns,
        &tr,
        &OraclePredictor::default(),
        &PerfModel::default(),
        &SimConfig {
            n_gpus: 10,
            billing: BillingMode::from_whole_gpu(whole_gpu),
            ..SimConfig::default()
        },
    )
}

fn all_three(preset: Preset) -> (RunReport, RunReport, RunReport) {
    let mut has = HybridAutoscaler::new(HybridConfig::default());
    let mut ks = KServePolicy::default();
    let mut fg = FastGSharePolicy::default();
    (
        run(&mut has, preset, false),
        run(&mut ks, preset, true),
        run(&mut fg, preset, false),
    )
}

#[test]
fn fig7_cost_ratios_match_paper_shape() {
    // Paper §4.3: "reduces function costs by an average of 10.8x [vs KServe]
    // and 1.72x [vs FaST-GShare]" — the average of per-function cost ratios.
    let (has, ks, fg) = all_three(Preset::Standard);
    let ratio_mean = |num: &RunReport, den: &RunReport| {
        let mut acc = 0.0;
        let mut n = 0;
        for (f, m) in &den.functions {
            let c_den = den.costs.cost_per_1k(f, m.served());
            let c_num = num.costs.cost_per_1k(f, num.functions[f].served());
            // Zero-served functions report 0.0 (not INFINITY): skip them.
            if c_den > 0.0 && c_num > 0.0 {
                acc += c_num / c_den;
                n += 1;
            }
        }
        acc / n as f64
    };
    let ks_ratio = ratio_mean(&ks, &has);
    let fg_ratio = ratio_mean(&fg, &has);
    // Paper: 10.8x and 1.72x. Our substrate reproduces the KServe gap's
    // direction and a 4-5x magnitude; the FaST gap compresses to ~1x because
    // our FaST replica policy is leaner than the original's (see
    // EXPERIMENTS.md §Fig7 for the full discussion) — assert it never
    // BEATS HAS-GPU by more than noise.
    assert!(ks_ratio > 3.5, "KServe/HAS mean per-function ratio {ks_ratio:.2}");
    assert!(fg_ratio > 0.85, "FaST/HAS mean per-function ratio {fg_ratio:.2}");
    assert!(
        has.costs.total_cost() < ks.costs.total_cost(),
        "aggregate ordering"
    );
}

#[test]
fn fig6_hasgpu_beats_fastgshare_on_violations() {
    // Paper: "Compared to FaST-GShare, HAS-GPU reduces SLO violations by an
    // average of 4.8x" (fixed slices + horizontal-only cold starts lose to
    // hybrid scaling). Averaged across functions at the 3x-5x band.
    let (has, _ks, fg) = all_three(Preset::Standard);
    let perf = PerfModel::default();
    let mut v_has_acc = 0.0;
    let mut v_fg_acc = 0.0;
    for (name, m) in &has.functions {
        let g = zoo_graph(ZooModel::from_name(name).unwrap());
        let baseline = perf.latency(&g, 1, 1.0, 1.0);
        for mult in [3.0, 4.0, 5.0] {
            v_has_acc += m.violation_rate(baseline * mult);
            v_fg_acc += fg.functions[name].violation_rate(baseline * mult);
        }
    }
    assert!(
        v_has_acc < v_fg_acc,
        "has-gpu violations {v_has_acc:.3} should undercut fast-gshare {v_fg_acc:.3}"
    );
}

#[test]
fn fig6_fastgshare_has_worst_tail_blowup() {
    // Cold-start-driven tails: FaST-GShare (horizontal-only, fine slices)
    // shows the worst p99/p50 blowup on the loaded functions.
    let (has, _ks, fg) = all_three(Preset::Standard);
    let blowup = |r: &RunReport, f: &str| {
        let mut s = r.functions[f].latency_summary();
        s.p99() / s.p50().max(1e-9)
    };
    // resnet50 is the contended CNN function in this workload.
    assert!(
        blowup(&fg, "resnet50") > blowup(&has, "resnet50"),
        "fg {} vs has {}",
        blowup(&fg, "resnet50"),
        blowup(&has, "resnet50")
    );
}

#[test]
fn stress_workload_amplifies_cost_gap() {
    let (has_std, ks_std, _) = all_three(Preset::Standard);
    let (has_str, ks_str, _) = all_three(Preset::Stress);
    let ratio = |h: &RunReport, k: &RunReport| k.costs.total_cost() / h.costs.total_cost();
    let std_ratio = ratio(&has_std, &ks_std);
    let stress_ratio = ratio(&has_str, &ks_str);
    // Paper: "a significant cost advantage, especially under stress".
    assert!(
        stress_ratio > std_ratio * 0.7,
        "std {std_ratio:.2} stress {stress_ratio:.2}"
    );
}

#[test]
fn served_plus_dropped_equals_arrivals() {
    // Conservation: the sim must not lose requests.
    let fns = functions();
    let tr = trace(&fns, Preset::Standard);
    let mut has = HybridAutoscaler::new(HybridConfig::default());
    let report = run_sim(
        &mut has,
        &fns,
        &tr,
        &OraclePredictor::default(),
        &PerfModel::default(),
        &SimConfig::default(),
    );
    // Arrivals are Poisson-thinned from the trace with the sim's seed; the
    // exact count equals the recorded outcomes (served + dropped).
    let recorded: usize = report
        .functions
        .values()
        .map(|m| m.served() + m.dropped())
        .sum();
    let expected: f64 = fns.iter().map(|f| tr.total_requests(&f.name)).sum();
    let rel = (recorded as f64 - expected).abs() / expected;
    assert!(rel < 0.1, "recorded {recorded} vs expected ~{expected}");
    assert!(recorded > 3000, "workload too small: {recorded}");
}

#[test]
fn hasgpu_uses_fewer_gpu_seconds_than_kserve() {
    let (has, ks, _) = all_three(Preset::Standard);
    let gs = |r: &RunReport| {
        r.functions
            .keys()
            .map(|f| r.costs.gpu_seconds_of(f))
            .sum::<f64>()
    };
    assert!(gs(&has) < gs(&ks) / 1.5, "has {} vs ks {}", gs(&has), gs(&ks));
}

#[test]
#[ignore] // diagnostic
fn diag_violation_rates() {
    let (has, ks, fg) = all_three(Preset::Standard);
    let perf = PerfModel::default();
    let g = zoo_graph(ZooModel::ResNet50);
    let baseline = perf.latency(&g, 1, 1.0, 1.0);
    println!("baseline = {:.2}ms", baseline * 1e3);
    for r in [&has, &ks, &fg] {
        print!("{:12}", r.platform);
        for mult in [1.5, 2.0, 2.5, 3.0, 5.0, 8.0] {
            let v = r.functions["resnet50"].violation_rate(baseline * mult);
            print!("  {mult}x:{:.3}", v);
        }
        let mut s = r.functions["resnet50"].latency_summary();
        println!("  p90={:.0}ms p95={:.0}ms p99={:.0}ms", s.p90()*1e3, s.p95()*1e3, s.p99()*1e3);
    }
}

#[test]
#[ignore] // diagnostic
fn diag_latency_timeline() {
    let fns = functions();
    let tr = trace(&fns, Preset::Standard);
    let mut has = HybridAutoscaler::new(HybridConfig::default());
    let r = run(&mut has, Preset::Standard, false);
    let m = &r.functions["resnet50"];
    let mut buckets: std::collections::BTreeMap<usize, (f64, usize)> = Default::default();
    for rec in &m.records {
        let b = (rec.arrival / 10.0) as usize;
        let e = buckets.entry(b).or_insert((0.0, 0));
        e.0 = e.0.max(rec.latency);
        e.1 += 1;
    }
    for (b, (maxl, n)) in &buckets {
        let rps = tr.rps_at("resnet50", b * 10 + 5);
        println!("t={:3}0s n={:5} max_lat={:8.1}ms trace_rps={:.0}", b, n, maxl * 1e3, rps);
    }
}

#[test]
#[ignore] // diagnostic
fn diag_platform_reports() {
    let (has, ks, fg) = all_three(Preset::Standard);
    for r in [&has, &ks, &fg] {
        println!(
            "== {} vups={} hups={} hdowns={}",
            r.platform, r.vertical_ups, r.horizontal_ups, r.horizontal_downs
        );
        for (f, m) in &r.functions {
            let mut s = m.latency_summary();
            println!("  {f}: served={} dropped={} p50={:.1}ms p99={:.1}ms cost={:.4}",
                m.served(), m.dropped(),
                if s.is_empty() {0.0} else {s.p50()*1e3},
                if s.is_empty() {0.0} else {s.p99()*1e3},
                r.costs.cost_of(f));
        }
    }
}
