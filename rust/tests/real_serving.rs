//! End-to-end **real mode**: the full three-layer stack — requests enter the
//! Rust gateway, are batched, gated on vGPU time tokens, and executed as
//! AOT-compiled HLO (JAX L2 + Pallas L1) on PJRT. Python is not running.
//!
//! Requires `make artifacts`; skips otherwise.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig};
use has_gpu::cluster::FunctionSpec;
use has_gpu::gateway::{Server, ServerConfig};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::rapp::OraclePredictor;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: real-mode serving needs the `pjrt` feature (--features pjrt)");
        return None;
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn functions() -> Vec<FunctionSpec> {
    // Real-mode functions are the small AOT models; the zoo graph drives the
    // perf/cost model on the control plane.
    vec![FunctionSpec {
        name: "cnn_s".into(),
        graph: zoo_graph(ZooModel::MobileNetV2),
        slo: 0.5,
        batch: 8,
        artifact: None, // resolved via manifest
    }]
}

fn start_server(n_gpus: usize) -> Option<Arc<Server>> {
    let dir = artifacts_dir()?;
    Some(
        Server::start(
            &dir,
            functions(),
            Box::new(HybridAutoscaler::new(HybridConfig {
                cooldown: 2.0,
                ..HybridConfig::default()
            })),
            Arc::new(OraclePredictor::default()),
            ServerConfig {
                n_gpus,
                tick: Duration::from_millis(300),
                ..ServerConfig::default()
            },
        )
        .expect("server starts"),
    )
}

#[test]
fn serves_single_request_end_to_end() {
    let Some(server) = start_server(1) else { return };
    let rx = server.submit("cnn_s", vec![0.5f32; 3 * 32 * 32]).expect("known function");
    let reply = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
    assert_eq!(reply.output.len(), 10);
    assert!(reply.output.iter().all(|v| v.is_finite()));
    assert!(reply.latency > Duration::ZERO);
    // An unknown function is a client error carrying the deployed menu —
    // never a panic in the gateway.
    let err = server.submit("no-such-fn", vec![0.0]).unwrap_err().to_string();
    assert!(err.contains("no-such-fn") && err.contains("cnn_s"), "{err}");
    server.shutdown();
}

#[test]
fn serves_concurrent_burst_with_batching() {
    let Some(server) = start_server(2) else { return };
    let n = 64;
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit("cnn_s", vec![i as f32 / n as f32; 3 * 32 * 32]).expect("known"))
        .collect();
    let mut batched = 0;
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(60)).expect("reply");
        assert_eq!(reply.output.len(), 10);
        if reply.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(batched > 0, "dynamic batching never engaged");
    let report = server.report();
    assert_eq!(report.functions["cnn_s"].served(), n);
    assert!(report.costs.cost_of("cnn_s") > 0.0, "billing must accrue");
    server.shutdown();
}

#[test]
fn sustained_load_triggers_scaling() {
    let Some(server) = start_server(2) else { return };
    // Sustained open-loop load for ~3 seconds.
    let mut pending = Vec::new();
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_secs(3) {
        pending.push(server.submit("cnn_s", vec![0.1f32; 3 * 32 * 32]).expect("known"));
        std::thread::sleep(Duration::from_millis(4));
        // Drain completed replies to bound memory.
        pending.retain(|rx| rx.try_recv().is_err());
    }
    // Allow in-flight work to finish.
    std::thread::sleep(Duration::from_millis(1500));
    let report = server.report();
    assert!(
        report.functions["cnn_s"].served() > 200,
        "served {}",
        report.functions["cnn_s"].served()
    );
    assert!(
        report.vertical_ups + report.horizontal_ups > 0,
        "no scaling under sustained load: {report:?}"
    );
    // Layout shows fine-grained slices, not whole GPUs.
    let layout = server.pod_layout();
    assert!(!layout.is_empty());
    server.shutdown();
}

#[test]
fn token_wait_reflects_quota_pressure() {
    let Some(server) = start_server(1) else { return };
    // With the single bootstrap pod at a small quota, a burst must show
    // token-gated waits in at least some replies.
    let rxs: Vec<_> = (0..48)
        .map(|_| server.submit("cnn_s", vec![0.2f32; 3 * 32 * 32]).expect("known"))
        .collect();
    let mut any_wait = Duration::ZERO;
    for rx in rxs {
        let reply = rx.recv_timeout(Duration::from_secs(60)).expect("reply");
        any_wait = any_wait.max(reply.token_wait);
    }
    // Token machinery is live (waits may legitimately be ~0 if the scaler
    // raised the quota quickly, so assert only on the mechanism's presence).
    let _ = any_wait;
    server.shutdown();
}
