//! Lock-free concurrent planning: many threads driving plan ticks and raw
//! predictions through ONE shared `RappPredictor` must produce exactly the
//! bits a single-threaded run produces. The forward scratch lives in
//! thread-local arenas (no `Mutex<ForwardScratch>` since the lane-parallel
//! rework), so the only shared mutable state is the memo and plan caches —
//! and a memoised value observed by one thread may have been computed by
//! another, which is only sound because every forward is a pure function of
//! the query. These tests are the cross-thread pin of that purity.

use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::cluster::reconfigurator::place_pod;
use has_gpu::cluster::{ClusterState, FunctionSpec, GpuId, Reconfigurator, ScalingAction};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::FeatureMode;
use has_gpu::rapp::{LatencyPredictor, PredictQuery, RappPredictor, RappWeights};

fn predictor(seed: u64) -> RappPredictor {
    RappPredictor::new(
        RappWeights::random(FeatureMode::Full, 32, seed),
        PerfModel::default(),
    )
}

/// One worker's deterministic plan-tick sequence: its own cluster, function,
/// autoscaler state, and demand profile — only the predictor is shared.
/// Returns every tick's action list.
fn tick_sequence(pred: &dyn LatencyPredictor, worker: u64) -> Vec<Vec<ScalingAction>> {
    let pm = PerfModel::default();
    let model = [ZooModel::ResNet50, ZooModel::MobileNetV2][worker as usize % 2];
    let spec = FunctionSpec {
        name: format!("f-{worker}"),
        graph: zoo_graph(model),
        slo: 0.25,
        batch: 8,
        artifact: None,
    };
    let mut cluster = ClusterState::new(4, pm.dev.mem_cap);
    cluster.register_function(spec.clone());
    let mut recon = Reconfigurator::new(&cluster, 1);
    place_pod(&mut recon, &mut cluster, &pm, &spec.name, GpuId(0), 500, 300, 8, 0.0).unwrap();
    let mut hs = HybridAutoscaler::new(HybridConfig::default());
    (0..40)
        .map(|t| {
            // Sawtooth demand phase-shifted per worker: scale-up, hysteresis,
            // and scale-down branches all fire across the run.
            let demand = 5.0 + 12.0 * ((t + worker) % 7) as f64;
            hs.plan(&spec, demand, &cluster, pred, t as f64)
        })
        .collect()
}

#[test]
fn concurrent_plan_ticks_match_the_single_threaded_sequences() {
    let shared = predictor(7);
    let workers: Vec<u64> = (0..4).collect();
    // Reference: each worker's sequence computed serially against a FRESH
    // predictor — no shared caches, no other threads.
    let reference: Vec<Vec<Vec<ScalingAction>>> = workers
        .iter()
        .map(|&w| tick_sequence(&predictor(7), w))
        .collect();
    // All workers concurrently against one shared predictor: plan caches and
    // memo tables race, forward arenas are thread-local.
    let concurrent: Vec<Vec<Vec<ScalingAction>>> = std::thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter()
            .map(|&w| {
                let p = &shared;
                s.spawn(move || tick_sequence(p, w))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (w, (got, want)) in concurrent.iter().zip(&reference).enumerate() {
        assert_eq!(
            got, want,
            "worker {w}: concurrent decision sequence diverged from single-threaded"
        );
    }
}

#[test]
fn shared_predictor_latencies_are_bit_identical_across_racing_threads() {
    // 8 threads hammer the SAME query grid through one predictor while each
    // checks every value against its own private predictor (same weights).
    // A memo hit may return a value computed by a different thread on a
    // different arena — it must still be the exact bits.
    let shared = predictor(11);
    let grid: Vec<(ZooModel, u32, f64, f64, f64)> = [ZooModel::ResNet50, ZooModel::BertTiny]
        .into_iter()
        .flat_map(|m| {
            (1..=10u32).flat_map(move |q| {
                [(m, 4u32, 0.5, q as f64 / 10.0, 1.0), (m, 8, 0.25, q as f64 / 10.0, 0.4)]
            })
        })
        .collect();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let shared = &shared;
            let grid = &grid;
            s.spawn(move || {
                let own = predictor(11);
                for &(m, batch, sm, quota, factor) in grid {
                    let g = zoo_graph(m);
                    let q = PredictQuery::new(&g, batch, sm, quota).with_factor(factor);
                    assert_eq!(
                        shared.latency(q).to_bits(),
                        own.latency(q).to_bits(),
                        "{m:?} b{batch} sm{sm} q{quota} f{factor}"
                    );
                    assert_eq!(shared.capacity(q).to_bits(), own.capacity(q).to_bits());
                }
                // Batched sweeps race the same lattice rows concurrently.
                let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
                let g = zoo_graph(ZooModel::ResNet50);
                let base = PredictQuery::new(&g, 4, 0.5, 1.0);
                let (mut a, mut b) = (Vec::new(), Vec::new());
                shared.latency_batch(base, &quotas, &mut a);
                own.latency_batch(base, &quotas, &mut b);
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            });
        }
    });
}
