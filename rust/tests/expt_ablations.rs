//! Platform-registry ablation tests: every registered platform — stock
//! trio, single-axis variants, static-predictor variant — runs end-to-end
//! through the matrix path and round-trips its cell JSON, and the paper's
//! design argument (hybrid scaling beats either axis alone) holds on the
//! standard preset.

use has_gpu::expt::{CellResult, PlatformRegistry, ScenarioMatrix};
use has_gpu::workload::Preset;

#[test]
fn registry_roundtrip_covers_every_platform_including_ablations() {
    let registry = PlatformRegistry::default();
    assert!(registry.specs().len() >= 6, "stock trio + 3 ablations minimum");
    for spec in registry.specs() {
        // name → spec → cell run → CellResult::to_json → from_json.
        let matrix = ScenarioMatrix {
            platforms: vec![spec.name.clone()],
            presets: vec![Preset::Standard],
            seeds: vec![3],
            seconds: 30,
            gpus: 4,
            rps: 30.0,
            ..ScenarioMatrix::default()
        };
        let cell = matrix.cells()[0].clone();
        assert_eq!(cell.platform, spec.name);
        let (report, result) = matrix.run_cell(&cell);
        assert_eq!(result.platform, spec.name);
        assert_eq!(
            report.platform, spec.name,
            "the policy must self-report its registry name"
        );
        let j = result.to_json();
        let back = CellResult::from_json(&j).expect(&spec.name);
        assert_eq!(back, result, "{}", spec.name);
        assert_eq!(
            back.to_json().to_string_pretty(),
            j.to_string_pretty(),
            "{} cell JSON must round-trip byte-identically",
            spec.name
        );
    }
}

#[test]
fn single_axis_ablations_express_their_restriction_in_the_grid() {
    // Vertical-only never scales horizontally after bootstrap; horizontal-
    // only never re-writes quotas. The scaling-action counters in the cell
    // results make the restriction observable from the export alone.
    let matrix = ScenarioMatrix {
        platforms: vec![
            "has-vertical-only".to_string(),
            "has-horizontal-only".to_string(),
        ],
        presets: vec![Preset::Standard],
        seeds: vec![3],
        seconds: 120,
        gpus: 8,
        rps: 150.0,
        ..ScenarioMatrix::default()
    };
    let report = matrix.run(2);
    let cell = |name: &str| report.cells.iter().find(|c| c.platform == name).unwrap();
    let vert = cell("has-vertical-only");
    // Bootstrap creates the initial pods before measurement; after that no
    // replica is ever added or removed, and quota re-writes do happen.
    assert_eq!(vert.horizontal_downs, 0, "{vert:?}");
    assert!(
        vert.vertical_ups + vert.vertical_downs > 0,
        "vertical-only must actually use its one axis: {vert:?}"
    );
    let horiz = cell("has-horizontal-only");
    assert_eq!(
        horiz.vertical_ups + horiz.vertical_downs,
        0,
        "horizontal-only must never re-write quotas: {horiz:?}"
    );
    assert!(
        horiz.horizontal_ups > 0,
        "horizontal-only must actually scale out: {horiz:?}"
    );
    assert!(vert.served > 0 && horiz.served > 0);
}

#[test]
fn hybrid_beats_both_single_axis_ablations_on_slo_violations() {
    // Paper §4 design argument: hybrid vertical+horizontal scaling beats
    // either axis alone. Seed-averaged SLO-violation rate on the standard
    // preset (drops count as violations), hybrid ≤ each single-axis variant.
    let matrix = ScenarioMatrix {
        platforms: vec![
            "has-gpu".to_string(),
            "has-vertical-only".to_string(),
            "has-horizontal-only".to_string(),
        ],
        presets: vec![Preset::Standard],
        seeds: vec![11, 12],
        seconds: 240,
        gpus: 10,
        rps: 150.0,
        ..ScenarioMatrix::default()
    };
    let report = matrix.run(0);
    let summary = report.summary();
    let rate = |name: &str| {
        summary
            .iter()
            .find(|r| r.platform == name)
            .unwrap()
            .slo_violation_rate
    };
    let (has, vert, horiz) = (
        rate("has-gpu"),
        rate("has-vertical-only"),
        rate("has-horizontal-only"),
    );
    assert!(
        has <= vert,
        "hybrid {has:.4} must not exceed vertical-only {vert:.4}"
    );
    assert!(
        has <= horiz,
        "hybrid {has:.4} must not exceed horizontal-only {horiz:.4}"
    );
    // And the export's ratio table carries the same story: every ablation
    // row reports its violation ratio vs has-gpu (≥ 1 when defined).
    let ratios = report.ratios_vs_has_gpu();
    assert_eq!(ratios.len(), 2);
    for r in &ratios {
        if let Some(v) = r.violation_ratio {
            assert!(v >= 1.0, "{}: {v}", r.platform);
        }
    }
}
