//! Fig. 5 — RaPP latency-prediction accuracy vs. the DIPPM baseline.
//!
//! Left: ConvNeXt predictions vs. ground truth across SM/quota allocations
//! (the models were trained on random graphs; ConvNeXt is an *unseen* model).
//! Right: MAPE for RaPP vs. DIPPM on validation / test / unseen splits
//! (training-side numbers from artifacts/rapp_meta.json) plus the unseen-zoo
//! MAPE measured natively in Rust.
//!
//! Requires `make artifacts`.

mod common;

use has_gpu::model::zoo::{zoo_graph, ZooModel, ALL_ZOO};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::dippm::DippmPredictor;
use has_gpu::rapp::{LatencyPredictor, PredictQuery, RappPredictor};
use has_gpu::util::bench::ascii_table;
use has_gpu::util::json;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("rapp_weights.json").exists() {
        eprintln!("SKIP fig5: run `make artifacts` first");
        return;
    }
    let pm = PerfModel::default();
    let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone()).unwrap();
    let dippm = DippmPredictor::load(&dir.join("dippm_weights.json"), pm.clone()).unwrap();

    // ---- Fig. 5 left: ConvNeXt predictions vs ground truth ---------------
    println!("\n=== Fig. 5 (left): ConvNeXt-Tiny latency — truth vs RaPP vs DIPPM (ms) ===");
    let g = zoo_graph(ZooModel::ConvNextTiny);
    let mut rows = Vec::new();
    for &(batch, sm, quota) in &[
        (1u32, 0.1f64, 0.4f64),
        (1, 0.35, 0.8),
        (4, 0.2, 0.2),
        (4, 0.5, 0.6),
        (8, 0.75, 1.0),
        (16, 0.3, 0.5),
        (32, 0.15, 0.9),
        (32, 1.0, 0.3),
    ] {
        let truth = pm.latency(&g, batch, sm, quota) * 1e3;
        let p_r = rapp.latency(PredictQuery::new(&g, batch, sm, quota)) * 1e3;
        let p_d = dippm.latency(PredictQuery::new(&g, batch, sm, quota)) * 1e3;
        rows.push(vec![
            format!("b{batch} sm{:.0}% q{:.0}%", sm * 100.0, quota * 100.0),
            format!("{truth:.2}"),
            format!("{p_r:.2}"),
            format!("{p_d:.2}"),
            format!("{:.1}%", ((p_r - truth) / truth).abs() * 100.0),
            format!("{:.1}%", ((p_d - truth) / truth).abs() * 100.0),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["config", "truth", "RaPP", "DIPPM", "RaPP err", "DIPPM err"],
            &rows
        )
    );

    // ---- Fig. 5 right: MAPE table ----------------------------------------
    println!("=== Fig. 5 (right): MAPE (%) ===");
    let meta = json::parse_file(&dir.join("rapp_meta.json")).unwrap();
    let mut rows = Vec::new();
    for model in ["rapp", "dippm"] {
        let m = meta.get(model).unwrap();
        rows.push(vec![
            model.to_string(),
            format!("{:.2}", m.get("val_mape").unwrap().as_f64().unwrap()),
            format!("{:.2}", m.get("test_mape").unwrap().as_f64().unwrap()),
            format!("{:.2}", m.get("unseen_mape").unwrap().as_f64().unwrap()),
        ]);
    }
    println!(
        "{}",
        ascii_table(&["model", "val", "test", "unseen-graphs"], &rows)
    );

    // Unseen *zoo* models (never in the python corpus), swept densely in Rust.
    let mut e_rapp = Vec::new();
    let mut e_dippm = Vec::new();
    for m in ALL_ZOO {
        let g = zoo_graph(m);
        for &batch in &[1u32, 4, 16] {
            for &sm in &[0.15f64, 0.4, 0.8] {
                for &q in &[0.25f64, 0.6, 1.0] {
                    let truth = pm.latency(&g, batch, sm, q);
                    let query = PredictQuery::new(&g, batch, sm, q);
                    e_rapp.push((rapp.latency(query) - truth).abs() / truth);
                    e_dippm.push((dippm.latency(query) - truth).abs() / truth);
                }
            }
        }
    }
    let mape = |v: &[f64]| 100.0 * v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "unseen ZOO models ({} configs): RaPP {:.2}%  DIPPM {:.2}%",
        e_rapp.len(),
        mape(&e_rapp),
        mape(&e_dippm)
    );
    println!("paper: RaPP ~5% flat; DIPPM 10.14% -> 17.7% degrading on unseen");
    println!("fig5 bench done");
}
