//! Fig. 6 — SLO-violation rates vs. baseline multiplier (1x..10x, step 0.25)
//! for HAS-GPU / KServe / FaST-GShare, plus the P90/P95/P99 tail table.
//!
//! Left plot: ResNet-50. Right: per-function violation rates relative to
//! HAS-GPU at the paper's highlighted multipliers.

mod common;

use common::{baseline_latency, functions, trace};
use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::baselines::{FastGSharePolicy, KServePolicy};
use has_gpu::metrics::{BillingMode, RunReport};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::sim::{run_sim, SimConfig};
use has_gpu::util::bench::ascii_table;
use has_gpu::workload::Preset;

fn run_all(seconds: usize) -> Vec<RunReport> {
    let fns = functions();
    let tr = trace(&fns, Preset::Standard, seconds);
    let pred = OraclePredictor::default();
    let perf = PerfModel::default();
    let mut out = Vec::new();
    let mut policies: Vec<(Box<dyn ScalingPolicy>, bool)> = vec![
        (Box::new(HybridAutoscaler::new(HybridConfig::default())), false),
        (Box::new(KServePolicy::default()), true),
        (Box::new(FastGSharePolicy::default()), false),
    ];
    for (policy, whole) in policies.iter_mut() {
        let cfg = SimConfig {
            n_gpus: 10,
            billing: BillingMode::from_whole_gpu(*whole),
            ..SimConfig::default()
        };
        out.push(run_sim(policy.as_mut(), &fns, &tr, &pred, &perf, &cfg));
    }
    out
}

fn main() {
    let fast = std::env::var("HAS_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let seconds = if fast { 180 } else { 480 };
    let reports = run_all(seconds);
    let perf = PerfModel::default();
    let fns = functions();

    // ---- Fig. 6 left: ResNet-50 violation curves --------------------------
    println!("\n=== Fig. 6 (left): ResNet-50 violation rate vs baseline multiplier ===");
    let rn = fns.iter().find(|f| f.name == "resnet50").unwrap();
    let base = baseline_latency(rn, &perf);
    let mut rows = Vec::new();
    let mut mult = 1.0;
    while mult <= 10.0 + 1e-9 {
        let mut row = vec![format!("{mult:.2}x")];
        for r in &reports {
            row.push(format!(
                "{:.3}",
                r.functions["resnet50"].violation_rate(base * mult)
            ));
        }
        rows.push(row);
        mult += 0.25;
    }
    println!(
        "{}",
        ascii_table(&["multiplier", "has-gpu", "kserve", "fast-gshare"], &rows)
    );

    // ---- Fig. 6 right: relative violation rates across all functions ------
    println!("=== Fig. 6 (right): violation rates by function @ 3x baseline (relative to HAS-GPU) ===");
    let mut rows = Vec::new();
    for f in &fns {
        let base = baseline_latency(f, &perf);
        let v: Vec<f64> = reports
            .iter()
            .map(|r| r.functions[&f.name].violation_rate(base * 3.0))
            .collect();
        let denom = v[0].max(1e-4);
        rows.push(vec![
            f.name.clone(),
            format!("{:.3}", v[0]),
            format!("{:.2}x", v[1] / denom),
            format!("{:.2}x", v[2] / denom),
        ]);
    }
    println!(
        "{}",
        ascii_table(&["function", "has-gpu (abs)", "kserve (rel)", "fast-gshare (rel)"], &rows)
    );

    // ---- tail latency table ------------------------------------------------
    println!("=== Fig. 6 tails: ResNet-50 P90 / P95 / P99 (ms) ===");
    let mut rows = Vec::new();
    for r in &reports {
        let mut s = r.functions["resnet50"].latency_summary();
        rows.push(vec![
            r.platform.clone(),
            format!("{:.1}", s.p90() * 1e3),
            format!("{:.1}", s.p95() * 1e3),
            format!("{:.1}", s.p99() * 1e3),
        ]);
    }
    println!("{}", ascii_table(&["platform", "P90", "P95", "P99"], &rows));

    // Headline factor: mean violation ratio FaST/HAS across functions+bands.
    let (mut v_has, mut v_fg) = (0.0, 0.0);
    for f in &fns {
        let base = baseline_latency(f, &perf);
        for m in [2.0, 3.0, 4.0, 5.0] {
            v_has += reports[0].functions[&f.name].violation_rate(base * m);
            v_fg += reports[2].functions[&f.name].violation_rate(base * m);
        }
    }
    println!(
        "FaST-GShare/HAS-GPU total violation ratio: {:.2}x (paper: 4.8x)",
        v_fg / v_has.max(1e-6)
    );
    println!("fig6 bench done");
}
