//! Fig. 4 — ResNet-152 inference latency under batch × SM × quota.
//!
//! Regenerates the paper's latency grid from the ground-truth perf model and
//! validates the *shape* against real token-scheduler runs (the no-debt
//! window semantics executed on wall-clock time). Prints the four qualitative
//! regimes the paper calls out.

mod common;

use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::util::bench::ascii_table;
use has_gpu::vgpu::tokens::TokenScheduler;
use has_gpu::vgpu::ClientId;

fn main() {
    let pm = PerfModel::default();
    let g = zoo_graph(ZooModel::ResNet152);

    println!("\n=== Fig. 4: ResNet-152 latency (ms) — batch x SM x quota ===");
    let sms = [0.1, 0.2, 0.35, 0.5, 0.75, 1.0];
    let quotas = [0.2, 0.4, 0.6, 0.8, 1.0];
    for &batch in &[1u32, 4, 16, 32] {
        let mut rows = Vec::new();
        for &sm in &sms {
            let mut row = vec![format!("sm={:.0}%", sm * 100.0)];
            for &q in &quotas {
                row.push(format!("{:.1}", pm.latency(&g, batch, sm, q) * 1e3));
            }
            rows.push(row);
        }
        let headers: Vec<String> = std::iter::once("batch".to_string())
            .chain(quotas.iter().map(|q| format!("q={:.0}%", q * 100.0)))
            .collect();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        println!("batch = {batch}");
        println!("{}", ascii_table(&h, &rows));
    }

    // The paper's observations, quantified:
    let quota_starved = pm.latency(&g, 32, 0.1, 0.4) / pm.latency(&g, 32, 0.1, 1.0);
    let quota_ample = pm.latency(&g, 8, 1.0, 0.4) / pm.latency(&g, 8, 1.0, 1.0);
    println!("quota gain (b32, sm10%): {quota_starved:.2}x vs (b8, sm100%): {quota_ample:.2}x");
    let sm_small_batch = pm.latency(&g, 1, 0.5, 1.0) / pm.latency(&g, 1, 1.0, 1.0);
    println!("small-batch SM insensitivity: lat(sm50%)/lat(sm100%) at b1 = {sm_small_batch:.3}");

    // Real token-scheduler validation: dilation measured on the wall clock.
    println!("\n--- real TokenScheduler validation (wall-clock) ---");
    let window = 0.005;
    for &(quota_mille, n_kernels, kernel_ms) in
        &[(200u32, 40u32, 0.5f64), (500, 40, 0.5), (1000, 40, 0.5), (300, 4, 30.0)]
    {
        let ts = TokenScheduler::new(window);
        ts.register(ClientId(1), quota_mille);
        let t0 = std::time::Instant::now();
        for _ in 0..n_kernels {
            ts.acquire(ClientId(1), kernel_ms / 1e3).unwrap();
            // Long kernels actually occupy wall time (non-preemptible).
            if kernel_ms >= 5.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(kernel_ms / 1e3));
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let raw = n_kernels as f64 * kernel_ms / 1e3;
        println!(
            "quota={:4}permille kernels={n_kernels:3}x{kernel_ms:4.1}ms raw={:6.1}ms measured={:7.1}ms dilation={:4.2}x",
            quota_mille,
            raw * 1e3,
            elapsed * 1e3,
            elapsed / raw
        );
    }
    println!("fig4 bench done");
}
