//! Fig. 7 — function costs ($ per 1K requests) under standard and stress
//! workloads for HAS-GPU / KServe / FaST-GShare, per function.

mod common;

use common::{functions, trace};
use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::baselines::{FastGSharePolicy, KServePolicy};
use has_gpu::metrics::{BillingMode, RunReport};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::OraclePredictor;
use has_gpu::sim::{run_sim, SimConfig};
use has_gpu::util::bench::ascii_table;
use has_gpu::workload::Preset;

fn run_all(preset: Preset, seconds: usize) -> Vec<RunReport> {
    let fns = functions();
    let tr = trace(&fns, preset, seconds);
    let pred = OraclePredictor::default();
    let perf = PerfModel::default();
    let mut out = Vec::new();
    let mut policies: Vec<(Box<dyn ScalingPolicy>, bool)> = vec![
        (Box::new(HybridAutoscaler::new(HybridConfig::default())), false),
        (Box::new(KServePolicy::default()), true),
        (Box::new(FastGSharePolicy::default()), false),
    ];
    for (policy, whole) in policies.iter_mut() {
        let cfg = SimConfig {
            n_gpus: 10,
            billing: BillingMode::from_whole_gpu(*whole),
            ..SimConfig::default()
        };
        out.push(run_sim(policy.as_mut(), &fns, &tr, &pred, &perf, &cfg));
    }
    out
}

fn main() {
    let fast = std::env::var("HAS_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let seconds = if fast { 180 } else { 480 };
    for preset in [Preset::Standard, Preset::Stress] {
        let reports = run_all(preset, seconds);
        println!("\n=== Fig. 7: $ per 1K requests — {preset:?} workload ===");
        let mut rows = Vec::new();
        let mut ratios_ks = Vec::new();
        let mut ratios_fg = Vec::new();
        for f in functions() {
            let per_1k: Vec<f64> = reports
                .iter()
                .map(|r| {
                    r.costs
                        .cost_per_1k(&f.name, r.functions[&f.name].served())
                })
                .collect();
            ratios_ks.push(per_1k[1] / per_1k[0]);
            ratios_fg.push(per_1k[2] / per_1k[0]);
            rows.push(vec![
                f.name.clone(),
                format!("{:.4}", per_1k[0]),
                format!("{:.4}", per_1k[1]),
                format!("{:.4}", per_1k[2]),
                format!("{:.1}x", per_1k[1] / per_1k[0]),
                format!("{:.1}x", per_1k[2] / per_1k[0]),
            ]);
        }
        println!(
            "{}",
            ascii_table(
                &["function", "has-gpu", "kserve", "fast-gshare", "ks/has", "fg/has"],
                &rows
            )
        );
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "mean per-function cost ratio: KServe/HAS = {:.1}x (paper: 10.8x)  FaST/HAS = {:.2}x (paper: 1.72x)",
            mean(&ratios_ks),
            mean(&ratios_fg)
        );
        println!(
            "aggregate $: has={:.3} kserve={:.3} fast-gshare={:.3}",
            reports[0].costs.total_cost(),
            reports[1].costs.total_cost(),
            reports[2].costs.total_cost()
        );
    }
    println!("fig7 bench done");
}
