//! Shared experiment setup for the paper-figure benches.
#![allow(dead_code)] // each bench binary uses its own subset of the helpers

use has_gpu::cluster::FunctionSpec;
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::workload::{Preset, Trace, TraceGen};

/// The benchmark function set (paper §4: MLPerf-based serverless functions).
pub fn functions() -> Vec<FunctionSpec> {
    let perf = PerfModel::default();
    [
        ZooModel::ResNet50,
        ZooModel::MobileNetV2,
        ZooModel::BertTiny,
        ZooModel::ConvNextTiny,
        ZooModel::Vgg16,
        ZooModel::DlrmSmall,
    ]
    .iter()
    .map(|&m| {
        let graph = zoo_graph(m);
        let baseline = perf.latency(&graph, 1, 1.0, 1.0);
        let slo = baseline * 3.0;
        let batch = [16u32, 8, 4, 2, 1]
            .into_iter()
            .find(|&b| perf.latency(&graph, b, 1.0, 1.0) <= slo * 0.5)
            .unwrap_or(1);
        FunctionSpec {
            name: graph.name.clone(),
            slo,
            batch,
            graph,
            artifact: None,
        }
    })
    .collect()
}

/// Azure-style experiment trace (longer than the integration tests').
pub fn trace(fns: &[FunctionSpec], preset: Preset, seconds: usize) -> Trace {
    let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    TraceGen::preset(preset, 11, seconds, 150.0).generate(&names)
}

/// Baseline ("pure container") latency per the paper's Fig. 6 definition.
pub fn baseline_latency(f: &FunctionSpec, perf: &PerfModel) -> f64 {
    perf.latency(&f.graph, 1, 1.0, 1.0)
}
