//! Scheduler hot-path microbenchmarks (§Perf targets in DESIGN.md):
//! token grant latency, vGPU allocation ops, autoscaler decision latency,
//! RaPP forwards (native vs PJRT), perf-model evaluation, sim event rate.

mod common;

use common::functions;
use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::cluster::reconfigurator::place_pod;
use has_gpu::cluster::{ClusterState, GpuId, Reconfigurator, ScalingAction};
use has_gpu::metrics::BillingMode;
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::{extract, FeatureMode, FeaturePlan};
use has_gpu::rapp::{
    CachedPredictor, CountingPredictor, LatencyPredictor, OraclePredictor, PredictQuery,
    RappPredictor, RappWeights,
};
use has_gpu::sim::{run_sim, SimConfig};
use has_gpu::simclock::EventQueue;
use has_gpu::util::bench::{black_box, Harness, BENCH_HOTPATH_SCHEMA};
use has_gpu::vgpu::tokens::TokenScheduler;
use has_gpu::vgpu::ClientId;
use has_gpu::workload::Preset;
use std::path::{Path, PathBuf};

fn main() {
    let mut h = Harness::new("scheduler_hotpath");
    let pm = PerfModel::default();
    let g = zoo_graph(ZooModel::ResNet50);

    // Token grant (uncontended; budget available).
    let ts = TokenScheduler::new(1.0); // long window: no refill churn
    ts.register(ClientId(1), 1000);
    h.bench("token_grant", || {
        black_box(ts.try_acquire(ClientId(1), 1e-9).ok());
    });

    // Perf-model latency evaluation (the RaPP feature hot loop).
    h.bench("perf_latency_resnet50_b8", || {
        black_box(pm.latency(&g, 8, 0.5, 0.6));
    });

    // One-shot feature extraction (full RaPP features incl. the 11 probe
    // evaluations) vs. the cached split: plan build once, dynamic fill per
    // query.
    h.bench("rapp_feature_extract", || {
        black_box(extract(&g, 8, 0.5, 0.6, &pm, FeatureMode::Full));
    });
    h.bench("rapp_feature_plan_build", || {
        black_box(FeaturePlan::new(&g, 8, &pm, FeatureMode::Full));
    });
    {
        let plan = FeaturePlan::new(&g, 8, &pm, FeatureMode::Full);
        let mut gf = Vec::new();
        let mut qi = 0u32;
        h.bench("rapp_feature_fill_dynamic", || {
            qi = qi % 997 + 1;
            plan.fill_graph_feats(0.5, qi as f64 / 1000.0, &mut gf);
            black_box(gf.last().copied());
        });
    }

    // Native RaPP forward: plan-cached miss (the autoscaler's cache-miss
    // cost) vs. the pre-FeaturePlan cost of re-deriving the plan per query,
    // plus the row-batched lattice pass. Deterministic random weights so the
    // bench runs without trained artifacts.
    {
        let rapp = RappPredictor::new(
            RappWeights::random(FeatureMode::Full, 32, 5),
            PerfModel::default(),
        );
        let mut qi = 0u32;
        let miss = h
            .bench("rapp_forward_plan_cached_miss", || {
                // Non-repeating sub-mille quotas: every call misses RaPP's
                // memo but hits the (graph, batch) plan.
                qi = qi % 9973 + 1;
                black_box(rapp.forward(&g, 8, 0.5, qi as f64 / 10007.0));
            })
            .median;
        let mut qj = 0u32;
        let replan = h
            .bench("rapp_forward_replan_each_query", || {
                qj = qj % 9973 + 1;
                rapp.reset_plan_cache();
                black_box(rapp.forward(&g, 8, 0.5, qj as f64 / 10007.0));
            })
            .median;
        let quotas: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();
        let mut out = Vec::new();
        h.bench_elems("rapp_forward_batch_lattice10", Some(10), || {
            rapp.forward_batch(&g, 8, 0.5, &quotas, &mut out);
            black_box(out.last().copied());
        });

        // Lane-parallel batched forward vs. its scalar-reference twin over a
        // wide lattice (64 rows = 8 full SIMD blocks). Both entries run the
        // identical plan/feature work, so the ratio isolates the lane kernel.
        let wide: Vec<f64> = (1..=64).map(|i| i as f64 / 64.0).collect();
        let mut out_simd = Vec::new();
        let mut out_ref = Vec::new();
        let simd = h
            .bench_elems("rapp_forward_simd", Some(64), || {
                rapp.forward_batch_at(&g, 8, 0.5, &wide, 1.0, &mut out_simd);
                black_box(out_simd.last().copied());
            })
            .median;
        let scalar = h
            .bench_elems("rapp_forward_scalar_ref", Some(64), || {
                rapp.forward_batch_scalar_ref(&g, 8, 0.5, &wide, 1.0, &mut out_ref);
                black_box(out_ref.last().copied());
            })
            .median;
        // The lanes must not change a single bit — the speedup is free.
        assert_eq!(
            out_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "SIMD lattice pass must be bit-identical to the scalar reference"
        );
        println!(
            "lane-parallel lattice speedup vs scalar reference: {:.1}x",
            scalar.as_secs_f64() / simd.as_secs_f64()
        );
        // ISSUE acceptance: ≥4x with lanes on. Enforced in full runs; smoke
        // mode only warns (timing noise on shared runners must not gate a
        // merge). With `--no-default-features` both entries take the scalar
        // path and the ratio is meaningless, so the gate is feature-scoped.
        if cfg!(feature = "simd") {
            let ok = scalar.as_secs_f64() >= 4.0 * simd.as_secs_f64();
            if has_gpu::util::bench::fast_mode() {
                if !ok {
                    println!(
                        "WARNING: lane-parallel ratio below 4x in smoke mode \
                         (scalar {scalar:?} vs simd {simd:?})"
                    );
                }
            } else {
                assert!(
                    ok,
                    "lane-parallel batched forward must be ≥4x faster than the \
                     scalar reference: scalar {scalar:?} vs simd {simd:?}"
                );
            }
        }
        println!(
            "cached-miss forward speedup vs per-query replan: {:.1}x",
            replan.as_secs_f64() / miss.as_secs_f64()
        );
        // ISSUE acceptance: ≥3x. Enforced in full runs; smoke mode (200 ms
        // windows on shared CI runners) only warns, so timing noise never
        // gates a merge — the non-blocking CI budget step reads the JSON.
        let ok = replan.as_secs_f64() >= 3.0 * miss.as_secs_f64();
        if has_gpu::util::bench::fast_mode() {
            if !ok {
                println!(
                    "WARNING: cached-miss ratio below 3x in smoke mode \
                     (replan {replan:?} vs miss {miss:?})"
                );
            }
        } else {
            assert!(
                ok,
                "FeaturePlan must make cached-miss forwards ≥3x faster than \
                 re-deriving the plan per query: replan {replan:?} vs miss {miss:?}"
            );
        }
    }

    // Trained-artifact forwards when available (kept for trajectory
    // comparability with earlier BENCH entries).
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("rapp_weights.json").exists() {
        let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone()).unwrap();
        h.bench("rapp_forward_native", || {
            black_box(rapp.forward(&g, 8, 0.5, 0.6));
        });
        h.bench("rapp_latency_cached", || {
            black_box(rapp.latency(PredictQuery::new(&g, 8, 0.5, 0.6)));
        });
    }

    // Autoscaler decision for a 10-GPU, ~40-pod cluster.
    let fns = functions();
    let mut cluster = ClusterState::new(10, pm.dev.mem_cap);
    for f in &fns {
        cluster.register_function(f.clone());
    }
    let mut recon = Reconfigurator::new(&cluster, 3);
    let mut placed = 0;
    'outer: for gpu in 0..10 {
        for slot in 0..4 {
            let f = &fns[(gpu + slot) % fns.len()];
            if place_pod(
                &mut recon, &mut cluster, &pm, &f.name, GpuId(gpu), 250, 250, f.batch, 0.0,
            )
            .is_ok()
            {
                placed += 1;
            }
            if placed >= 40 {
                break 'outer;
            }
        }
    }
    let pred = OraclePredictor::default();
    let mut scaler = HybridAutoscaler::new(HybridConfig::default());
    let mut t = 0.0;
    h.bench("autoscaler_plan_40pods", || {
        t += 1.0;
        black_box(scaler.plan(&fns[0], 120.0, &cluster, &pred, t));
    });

    // The same tick through the quantized capacity cache — the sim's actual
    // configuration (DESIGN.md target: < 1 ms at 10 GPUs / ~40 pods).
    let cached_oracle = CachedPredictor::new(&pred);
    let mut scaler_cached = HybridAutoscaler::new(HybridConfig::default());
    let mut tc = 0.0;
    h.bench("autoscaler_plan_40pods_cached", || {
        tc += 1.0;
        black_box(scaler_cached.plan(&fns[0], 120.0, &cluster, &cached_oracle, tc));
    });

    // GPU-occupancy scans the plan tick runs per function per tick: the
    // iterator-based used/idle walks and the HGO argmin must stay
    // allocation-free and far under the plan budget (the seed allocated a
    // fresh Vec per call — this entry pins the fix).
    h.bench("cluster_used_gpus_scan", || {
        black_box(cluster.used_gpus().count());
        black_box(cluster.least_occupied_used_gpu());
        black_box(cluster.idle_gpu());
    });

    // Fault-injection overhead: compiling a 10-GPU / 300 s chaos schedule —
    // what every fault cell pays once before the event loop — stays far off
    // the per-tick path, and the entry pins it (budget in ci.yml).
    {
        use has_gpu::sim::{fault_spec_from_name, FaultPlan};
        let chaos = fault_spec_from_name("chaos-gpu-failures").unwrap();
        h.bench("fault_tick_overhead", || {
            let plan = FaultPlan::compile(&chaos, 11, 10, 300.0);
            let mut n = 0usize;
            for &(t, _) in plan.events() {
                n += (t < 300.0) as usize;
            }
            black_box(n);
        });

        // Recovery replan: the same 40-pod shape as autoscaler_plan_40pods,
        // but GPU 0 is down and its pods evicted — the per-tick cost of
        // routing around the hole and proposing replacement replicas while
        // a device is dead.
        let mut rec_cluster = ClusterState::new(10, pm.dev.mem_cap);
        for f in &fns {
            rec_cluster.register_function(f.clone());
        }
        let mut rec_recon = Reconfigurator::new(&rec_cluster, 3);
        let mut placed = 0;
        'outer_r: for gpu in 0..10 {
            for slot in 0..4 {
                let f = &fns[(gpu + slot) % fns.len()];
                if place_pod(
                    &mut rec_recon, &mut rec_cluster, &pm, &f.name, GpuId(gpu), 250, 250,
                    f.batch, 0.0,
                )
                .is_ok()
                {
                    placed += 1;
                }
                if placed >= 40 {
                    break 'outer_r;
                }
            }
        }
        rec_cluster.set_gpu_down(GpuId(0), true);
        for pod in rec_cluster.pods_on(GpuId(0)) {
            rec_recon.evict_pod(&mut rec_cluster, pod);
        }
        let cached_rec = CachedPredictor::new(&pred);
        let mut scaler_rec = HybridAutoscaler::new(HybridConfig::default());
        let mut tr = 0.0;
        h.bench("recovery_replan_40pods", || {
            tr += 1.0;
            black_box(scaler_rec.plan(&fns[0], 120.0, &rec_cluster, &cached_rec, tr));
        });
    }

    // Class-aware planning on a mixed fleet (cheapest-feasible-class
    // placement + per-pod class factors) — same shape as the 40-pod tick so
    // the heterogeneity overhead is directly readable from the two entries.
    {
        use has_gpu::vgpu::GpuClass;
        let fleet: Vec<GpuClass> = (0..10)
            .map(|i| match i % 4 {
                0 => GpuClass::a100(),
                1 | 2 => GpuClass::v100(),
                _ => GpuClass::t4(),
            })
            .collect();
        let mut mixed = ClusterState::from_classes(&fleet);
        for f in &fns {
            mixed.register_function(f.clone());
        }
        let mut recon_m = Reconfigurator::new(&mixed, 4);
        let mut placed = 0;
        'outer_m: for gpu in 0..10 {
            for slot in 0..4 {
                let f = &fns[(gpu + slot) % fns.len()];
                if place_pod(
                    &mut recon_m, &mut mixed, &pm, &f.name, GpuId(gpu), 250, 250, f.batch, 0.0,
                )
                .is_ok()
                {
                    placed += 1;
                }
                if placed >= 40 {
                    break 'outer_m;
                }
            }
        }
        let cached_mixed = CachedPredictor::new(&pred);
        let mut scaler_mixed = HybridAutoscaler::new(HybridConfig::default());
        let mut tm = 0.0;
        h.bench("autoscaler_plan_40pods_mixed_fleet", || {
            tm += 1.0;
            black_box(scaler_mixed.plan(&fns[0], 120.0, &mixed, &cached_mixed, tm));
        });
    }

    // Predictor-invocation accounting (ISSUE acceptance): over a run of
    // identical plan ticks, the cache must cut underlying predictor forwards
    // by ≥ 5x versus the uncached path.
    {
        let ticks = 50;
        let raw = CountingPredictor::new(OraclePredictor::default());
        let mut s1 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            black_box(s1.plan(&fns[0], 120.0, &cluster, &raw, t as f64));
        }
        let uncached = raw.invocations();
        let counted = CountingPredictor::new(OraclePredictor::default());
        let cache = CachedPredictor::new(&counted);
        let mut s2 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            black_box(s2.plan(&fns[0], 120.0, &cluster, &cache, t as f64));
        }
        let cached = counted.invocations();
        println!(
            "predictor invocations over {ticks} plan ticks: uncached={uncached} \
             cached={cached} ({:.1}x fewer)",
            uncached as f64 / cached.max(1) as f64
        );
        assert!(
            uncached >= 5 * cached.max(1),
            "capacity cache must cut predictor invocations ≥5x: {uncached} vs {cached}"
        );
    }

    // Pod lifecycle swap round-trip: demote to the host tier and promote
    // back through the reconfigurator — the keep-alive hot path a
    // lifecycle-aware planner pays per parked/revived replica.
    {
        let pod = cluster.pods_of(&fns[0].name)[0].id;
        let mut t_swap = 10_000.0;
        h.bench("pod_swap_tick", || {
            t_swap += 1.0;
            recon
                .apply(&mut cluster, &pm, &ScalingAction::DemotePod { pod }, t_swap)
                .unwrap();
            t_swap += 1.0;
            recon
                .apply(&mut cluster, &pm, &ScalingAction::PromotePod { pod }, t_swap)
                .unwrap();
            black_box(pod);
        });
    }

    // TTFT percentile extraction at reporting scale: 5k wait samples into a
    // Summary, P50 + P99 out — what every lifecycle cell pays at End.
    {
        use has_gpu::util::stats::Summary;
        let samples: Vec<f64> = (0..5000u64)
            .map(|i| (i.wrapping_mul(2654435761) % 1000) as f64 / 1000.0)
            .collect();
        h.bench_elems("ttft_summary_5k", Some(5000), || {
            let mut s = Summary::new();
            for &v in &samples {
                s.add(v);
            }
            black_box((s.p50(), s.p99()));
        });
    }

    // vGPU allocation round-trip.
    let mut vg = has_gpu::vgpu::VGpu::new("GPU-bench", 16e9);
    let mut id = 1000u64;
    h.bench("vgpu_attach_detach", || {
        id += 1;
        let c = ClientId(id);
        vg.attach(c, 250, 500, 1e8).unwrap();
        vg.detach(c, 1e8).unwrap();
    });

    // Discrete-event queue throughput.
    h.bench_elems("event_queue_push_pop", Some(64), || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..64u64 {
            q.push_at(i as f64 * 0.5, i);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });

    // Oracle predictor via trait object (the sim's inner loop).
    let pred_dyn: &dyn LatencyPredictor = &pred;
    h.bench("predictor_capacity_dyn", || {
        black_box(pred_dyn.capacity(PredictQuery::new(&g, 8, 0.5, 0.6)));
    });

    // End-to-end sim event rate on the standard preset: requests processed
    // per second of wall clock through the streaming event core (arrival
    // cursor + pooled batch buffers). The queue's high-water mark is printed
    // so the O(in-flight) claim is visible in bench logs.
    {
        let seconds = if has_gpu::util::bench::fast_mode() { 60 } else { 180 };
        let fns = functions();
        let trace = common::trace(&fns, Preset::Standard, seconds);
        let perf = PerfModel::default();
        let requests: u64 = fns
            .iter()
            .map(|f| trace.total_requests(&f.name) as u64)
            .sum();
        let mut peak = 0usize;
        h.bench_elems("sim_standard_requests", Some(requests), || {
            let mut policy = HybridAutoscaler::new(HybridConfig::default());
            let pred = OraclePredictor::default();
            let r = run_sim(
                &mut policy,
                &fns,
                &trace,
                &pred,
                &perf,
                &SimConfig::for_experiment(10, 11, BillingMode::FineGrained),
            );
            peak = r.event_queue_peak;
            black_box(r.total_served());
        });
        println!(
            "sim event-queue high water: {peak} (trace carries {requests} requests)"
        );
    }

    // Trace-backend throughput: the TraceAzureSmall population (48 sampled
    // functions, heavy-tail popularity, duty-cycled diurnal rates) through
    // the active-set planner on a cold cluster — requests per wall-clock
    // second at sampled-trace scale (budget in ci.yml).
    {
        use has_gpu::workload::TraceSource;
        let seconds = if has_gpu::util::bench::fast_mode() { 60 } else { 180 };
        let perf = PerfModel::default();
        let src = TraceSource::for_preset(Preset::TraceAzureSmall, 11, seconds, 150.0)
            .expect("trace preset");
        let (fns, trace) = src.sample(&perf);
        let requests: u64 = fns
            .iter()
            .map(|f| trace.total_requests(&f.name) as u64)
            .sum();
        h.bench_elems("sim_request_rate", Some(requests), || {
            let mut policy = HybridAutoscaler::new(HybridConfig::default());
            let pred = OraclePredictor::default();
            let mut cfg = SimConfig::for_experiment(10, 11, BillingMode::FineGrained);
            cfg.warm_start = false;
            cfg.idle_sweep = 8;
            let r = run_sim(&mut policy, &fns, &trace, &pred, &perf, &cfg);
            black_box(r.total_served());
        });
    }

    // Population-scale planner tick: the 100k-function TraceAzureScale cell.
    // A full scan would plan 100 000 functions every tick; the active-set
    // loop touches only the handful with arrivals, queue, or pods, so the
    // per-tick cost is what this entry pins (budget in ci.yml). The horizon
    // is deliberately short — the entry measures the planner loop and the
    // sharded metrics plane, not a long serving run.
    {
        use has_gpu::workload::TraceSource;
        let seconds = if has_gpu::util::bench::fast_mode() { 5 } else { 15 };
        let perf = PerfModel::default();
        let src = TraceSource::for_preset(Preset::TraceAzureScale, 11, seconds, 200.0)
            .expect("trace preset");
        let (fns, trace) = src.sample(&perf);
        let mut touched = 0usize;
        h.bench_elems("trace_tick_100k_fns", Some(seconds as u64), || {
            let mut policy = HybridAutoscaler::new(HybridConfig::default());
            let pred = OraclePredictor::default();
            let mut cfg = SimConfig::for_experiment(10, 11, BillingMode::FineGrained);
            cfg.warm_start = false;
            cfg.idle_sweep = 8;
            cfg.drain = 10.0;
            let r = run_sim(&mut policy, &fns, &trace, &pred, &perf, &cfg);
            touched = r.total_served() + r.total_dropped();
            black_box(touched);
        });
        println!(
            "trace_tick_100k_fns: {} functions in population, {touched} requests touched",
            fns.len()
        );
    }

    // Workflow routing tick: open an origin at the entry stage, route the
    // detector completion across its hop, join at the classifier, and close
    // the origin — the full per-request router cost of the 2-stage vision
    // chain (budget in ci.yml: < 5 µs). The router is recycled every 64k
    // origins so the bench measures routing, not unbounded origin growth.
    {
        use has_gpu::gateway::{StageHop, WorkflowRouter};
        use has_gpu::workflow::WorkflowRegistry;
        let reg = WorkflowRegistry::default();
        let wf = reg.get("pipeline-vision").unwrap().clone();
        let mut router = WorkflowRouter::new(&wf);
        let mut hops: Vec<StageHop> = Vec::new();
        let mut opened = 0u32;
        let mut tw = 0.0;
        h.bench("workflow_route_tick", || {
            if opened == 1 << 16 {
                router = WorkflowRouter::new(&wf);
                opened = 0;
            }
            tw += 1.0;
            let o = router.open(tw);
            opened += 1;
            let early = router.route_completion(o, 0, tw + 0.01, &mut hops);
            debug_assert!(early.is_none() && hops.len() == 1);
            let to = hops[0].to;
            let e2e = if router.arrive(o, to) {
                router.route_completion(o, to, tw + 0.02, &mut hops)
            } else {
                None
            };
            black_box(e2e);
        });
    }

    // SLO budget split over a 16-stage chain — the renormalization cost a
    // co-scaling pass pays per workflow per tick (budget in ci.yml: < 20 µs).
    {
        use has_gpu::workflow::{Workflow, IMAGE_TENSOR_BYTES};
        let names: Vec<String> = (0..16).map(|i| format!("s{i}")).collect();
        let stages: Vec<(&str, ZooModel, u32)> = names
            .iter()
            .map(|n| (n.as_str(), ZooModel::MobileNetV2, 8))
            .collect();
        let mut wf16 =
            Workflow::chain("bench-16", "16-stage split bench", &stages, IMAGE_TENSOR_BYTES);
        wf16.e2e_slo = 0.5;
        let lats: Vec<f64> = (0..16).map(|i| 0.002 + i as f64 * 1e-4).collect();
        h.bench("budget_split_16stage", || {
            black_box(wf16.stage_budgets(&lats));
        });
    }

    // First BENCH_hotpath.json trajectory point (schema
    // has-gpu/bench-hotpath/v1); CI uploads it as an artifact. `cargo bench`
    // runs with the package dir as cwd, so HAS_BENCH_OUT lets CI pin an
    // absolute destination.
    let out = std::env::var("HAS_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    let out = Path::new(&out);
    h.write_json(out, BENCH_HOTPATH_SCHEMA).expect("write BENCH_hotpath.json");
    println!("wrote {}", out.display());

    println!("scheduler_hotpath done");
}
