//! Scheduler hot-path microbenchmarks (§Perf targets in DESIGN.md):
//! token grant latency, vGPU allocation ops, autoscaler decision latency,
//! RaPP forwards (native vs PJRT), perf-model evaluation, sim event rate.

mod common;

use common::functions;
use has_gpu::autoscaler::{HybridAutoscaler, HybridConfig, ScalingPolicy};
use has_gpu::cluster::reconfigurator::place_pod;
use has_gpu::cluster::{ClusterState, GpuId, Reconfigurator};
use has_gpu::model::zoo::{zoo_graph, ZooModel};
use has_gpu::perf::PerfModel;
use has_gpu::rapp::features::{extract, FeatureMode};
use has_gpu::rapp::{
    CachedPredictor, CountingPredictor, LatencyPredictor, OraclePredictor, RappPredictor,
};
use has_gpu::simclock::EventQueue;
use has_gpu::util::bench::{black_box, Harness};
use has_gpu::vgpu::tokens::TokenScheduler;
use has_gpu::vgpu::ClientId;
use std::path::PathBuf;

fn main() {
    let mut h = Harness::new("scheduler_hotpath");
    let pm = PerfModel::default();
    let g = zoo_graph(ZooModel::ResNet50);

    // Token grant (uncontended; budget available).
    let ts = TokenScheduler::new(1.0); // long window: no refill churn
    ts.register(ClientId(1), 1000);
    h.bench("token_grant", || {
        black_box(ts.try_acquire(ClientId(1), 1e-9).ok());
    });

    // Perf-model latency evaluation (the RaPP feature hot loop).
    h.bench("perf_latency_resnet50_b8", || {
        black_box(pm.latency(&g, 8, 0.5, 0.6));
    });

    // Feature extraction (full RaPP features incl. 11 probe evaluations).
    h.bench("rapp_feature_extract", || {
        black_box(extract(&g, 8, 0.5, 0.6, &pm, FeatureMode::Full));
    });

    // Native RaPP forward (uncached + cached).
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("rapp_weights.json").exists() {
        let rapp = RappPredictor::load(&dir.join("rapp_weights.json"), pm.clone()).unwrap();
        h.bench("rapp_forward_native", || {
            black_box(rapp.forward(&g, 8, 0.5, 0.6));
        });
        h.bench("rapp_latency_cached", || {
            black_box(rapp.latency(&g, 8, 0.5, 0.6));
        });
    }

    // Autoscaler decision for a 10-GPU, ~40-pod cluster.
    let fns = functions();
    let mut cluster = ClusterState::new(10, pm.dev.mem_cap);
    for f in &fns {
        cluster.register_function(f.clone());
    }
    let mut recon = Reconfigurator::new(&cluster, 3);
    let mut placed = 0;
    'outer: for gpu in 0..10 {
        for slot in 0..4 {
            let f = &fns[(gpu + slot) % fns.len()];
            if place_pod(
                &mut recon, &mut cluster, &pm, &f.name, GpuId(gpu), 250, 250, f.batch, 0.0,
            )
            .is_ok()
            {
                placed += 1;
            }
            if placed >= 40 {
                break 'outer;
            }
        }
    }
    let pred = OraclePredictor::default();
    let mut scaler = HybridAutoscaler::new(HybridConfig::default());
    let mut t = 0.0;
    h.bench("autoscaler_plan_40pods", || {
        t += 1.0;
        black_box(scaler.plan(&fns[0], 120.0, &cluster, &pred, t));
    });

    // The same tick through the quantized capacity cache — the sim's actual
    // configuration (DESIGN.md target: < 1 ms at 10 GPUs / ~40 pods).
    let cached_oracle = CachedPredictor::new(&pred);
    let mut scaler_cached = HybridAutoscaler::new(HybridConfig::default());
    let mut tc = 0.0;
    h.bench("autoscaler_plan_40pods_cached", || {
        tc += 1.0;
        black_box(scaler_cached.plan(&fns[0], 120.0, &cluster, &cached_oracle, tc));
    });

    // Predictor-invocation accounting (ISSUE acceptance): over a run of
    // identical plan ticks, the cache must cut underlying predictor forwards
    // by ≥ 5x versus the uncached path.
    {
        let ticks = 50;
        let raw = CountingPredictor::new(OraclePredictor::default());
        let mut s1 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            black_box(s1.plan(&fns[0], 120.0, &cluster, &raw, t as f64));
        }
        let uncached = raw.invocations();
        let counted = CountingPredictor::new(OraclePredictor::default());
        let cache = CachedPredictor::new(&counted);
        let mut s2 = HybridAutoscaler::new(HybridConfig::default());
        for t in 0..ticks {
            black_box(s2.plan(&fns[0], 120.0, &cluster, &cache, t as f64));
        }
        let cached = counted.invocations();
        println!(
            "predictor invocations over {ticks} plan ticks: uncached={uncached} \
             cached={cached} ({:.1}x fewer)",
            uncached as f64 / cached.max(1) as f64
        );
        assert!(
            uncached >= 5 * cached.max(1),
            "capacity cache must cut predictor invocations ≥5x: {uncached} vs {cached}"
        );
    }

    // vGPU allocation round-trip.
    let mut vg = has_gpu::vgpu::VGpu::new("GPU-bench", 16e9);
    let mut id = 1000u64;
    h.bench("vgpu_attach_detach", || {
        id += 1;
        let c = ClientId(id);
        vg.attach(c, 250, 500, 1e8).unwrap();
        vg.detach(c, 1e8).unwrap();
    });

    // Discrete-event queue throughput.
    h.bench_elems("event_queue_push_pop", Some(64), || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..64u64 {
            q.push_at(i as f64 * 0.5, i);
        }
        while let Some(x) = q.pop() {
            black_box(x);
        }
    });

    // Oracle predictor via trait object (the sim's inner loop).
    let pred_dyn: &dyn LatencyPredictor = &pred;
    h.bench("predictor_capacity_dyn", || {
        black_box(pred_dyn.capacity(&g, 8, 0.5, 0.6));
    });

    println!("scheduler_hotpath done");
}
